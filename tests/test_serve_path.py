"""Tests for the fused decode->predict serving path (ISSUE 1):

* table-driven canonical Huffman decoder (LUT + per-length first_code /
  rank_base tables, vectorized whole-stream decode) vs the bit-at-a-time
  oracle, including degenerate and max-length alphabets;
* vectorized LZW / Zaks / arithmetic decoders vs their reference twins;
* predict_compressed: bit-exact across engines and vs the uncompressed
  forest, on both tasks;
* the fused-aggregation Pallas kernel vs the (T, N) kernel's reduced result;
* the float32 one-hot precision guard at the 2**24 boundary;
* the streamed serve driver vs predict_compressed.
"""
import numpy as np
import pytest

from repro.core import CompressedForest, compress_forest, predict_compressed
from repro.core.arithmetic import ArithmeticCode
from repro.core.bitio import BitReader
from repro.core.compressed_predict import iter_trees
from repro.core.huffman import HuffmanCode, build_decode_tables
from repro.core.lz import (
    lzw_decode_bits,
    lzw_decode_bits_reference,
    lzw_encode_bits,
)
from repro.core.vechuff import VectorHuffman
from repro.core.zaks import zaks_decode, zaks_decode_reference, zaks_encode

from conftest import random_forest, random_tree


def random_codebook(rng, max_alphabet=80, skewed=False):
    b = int(rng.integers(2, max_alphabet))
    freqs = rng.integers(0, 1000, b)
    if skewed:  # exponential freqs force long codes
        freqs = (2.0 ** rng.integers(0, 30, b)).astype(np.int64) * (freqs > 0)
    if (freqs > 0).sum() == 0:
        freqs[0] = 1
    return freqs


class TestTableDrivenHuffman:
    @pytest.mark.parametrize("skewed", [False, True])
    def test_roundtrip_vs_bitwise(self, rng, skewed):
        for trial in range(40):
            freqs = random_codebook(rng, skewed=skewed)
            code = HuffmanCode.from_freqs(freqs)
            support = np.flatnonzero(freqs > 0)
            n = int(rng.integers(1, 300))
            p = freqs[support] / freqs[support].sum()
            syms = rng.choice(support, size=n, p=p)
            blob = code.encode(syms)
            # whole-stream vectorized decode
            assert np.array_equal(code.decode(blob, n), syms)
            # symbol-at-a-time LUT decode tracks the bitwise oracle exactly
            r1, r2 = BitReader(blob), BitReader(blob)
            for want in syms:
                assert code.decode_symbol(r1) == want
                assert code.decode_symbol_bitwise(r2) == want
                assert r1.pos == r2.pos

    def test_degenerate_single_symbol_alphabet(self):
        freqs = np.zeros(7, np.int64)
        freqs[4] = 3
        code = HuffmanCode.from_freqs(freqs)
        syms = np.full(25, 4)
        blob = code.encode(syms)
        assert np.array_equal(code.decode(blob, 25), syms)
        r = BitReader(blob)
        assert all(code.decode_symbol(r) == 4 for _ in range(25))

    def test_max_length_alphabet(self, rng):
        """Fibonacci frequencies give code lengths ~ alphabet size, well past
        the 12-bit LUT — exercises the per-length canonical fallback."""
        b = 44
        freqs = np.array([1, 1] + [0] * (b - 2), np.int64)
        for i in range(2, b):
            freqs[i] = freqs[i - 1] + freqs[i - 2]
        code = HuffmanCode.from_freqs(freqs)
        assert int(code.lengths.max()) > 30
        syms = rng.choice(b, 2000, p=freqs / freqs.sum())
        blob = code.encode(syms)
        assert np.array_equal(code.decode(blob, 2000), syms)
        assert np.array_equal(code.decode_bitwise(blob, 2000), syms)

    def test_truncated_stream_raises(self, rng):
        freqs = rng.integers(1, 50, 20)
        code = HuffmanCode.from_freqs(freqs)
        syms = rng.integers(0, 20, 500)
        blob = code.encode(syms)
        with pytest.raises(ValueError):
            code.decode(blob[: len(blob) // 8], 500)

    def test_decode_symbol_truncated_raises(self, rng):
        """decode_symbol must refuse to consume a code that runs past the
        payload instead of resolving zero padding into a phantom symbol."""
        freqs = rng.integers(1, 50, 30)
        code = HuffmanCode.from_freqs(freqs)
        syms = rng.integers(0, 30, 100)
        blob = code.encode(syms)[:2]
        r = BitReader(blob)
        with pytest.raises(ValueError):
            for _ in range(100):
                code.decode_symbol(r)

    def test_sparse_and_dense_strategies_agree(self, rng):
        """decode_stream picks a python LUT-chase for sparse streams and the
        all-bit-positions pass for dense ones; both must agree."""
        from repro.core.vechuff import decode_stream

        freqs = rng.integers(1, 30, 3000)  # big alphabet -> long codes
        code = HuffmanCode.from_freqs(freqs)
        syms = rng.integers(0, 3000, 400)
        blob = code.encode(syms)
        t = code.tables()
        assert np.array_equal(decode_stream(t, blob, 400), syms)
        # dense: tiny alphabet, short codes
        freqs = np.array([900, 80, 15, 5])
        code = HuffmanCode.from_freqs(freqs)
        syms = rng.choice(4, 5000, p=freqs / freqs.sum())
        blob = code.encode(syms)
        assert np.array_equal(code.decode(blob, 5000), syms)

    def test_vector_huffman_encode_decode_consistent(self, rng):
        freqs = random_codebook(rng)
        code = HuffmanCode.from_freqs(freqs)
        vh = VectorHuffman(code.lengths)
        support = np.flatnonzero(freqs > 0)
        syms = rng.choice(support, 200)
        blob, nbits = vh.encode(syms)
        assert blob == code.encode(syms)  # same canonical codes
        assert np.array_equal(vh.decode(blob, 200), syms)
        assert np.array_equal(vh.decode_streams([blob], [200])[0], syms)

    def test_tables_match_canonical_codes(self, rng):
        from repro.core.huffman import canonical_codes

        freqs = random_codebook(rng)
        code = HuffmanCode.from_freqs(freqs)
        t = build_decode_tables(code.lengths)
        codes = canonical_codes(code.lengths)
        for rank, sym in enumerate(t.sym_by_rank):
            c, l = codes[int(sym)]
            assert int(t.rank_base[l]) <= rank
            assert c == int(t.first_code[l]) + rank - int(t.rank_base[l])


class TestReferenceParity:
    def test_lzw_vectorized_matches_reference(self, rng):
        for _ in range(20):
            bits = (rng.random(int(rng.integers(1, 4000))) < 0.4).astype(
                np.uint8
            )
            payload = lzw_encode_bits(bits)
            got = lzw_decode_bits(payload, len(bits))
            ref = lzw_decode_bits_reference(payload, len(bits))
            assert np.array_equal(got, bits)
            assert np.array_equal(ref, bits)

    def test_zaks_vectorized_matches_reference(self, rng):
        for _ in range(50):
            t = random_tree(rng, d=4, max_depth=int(rng.integers(1, 12)))
            z = zaks_encode(t)
            l1, r1, leaf1 = zaks_decode(z)
            l2, r2, leaf2 = zaks_decode_reference(z)
            assert np.array_equal(l1, l2)
            assert np.array_equal(r1, r2)
            assert np.array_equal(leaf1, leaf2)

    def test_zaks_invalid_raises(self):
        with pytest.raises(ValueError):
            zaks_decode(np.array([1, 0], np.uint8))
        with pytest.raises(ValueError):
            zaks_decode(np.array([0, 0, 0], np.uint8))

    def test_arithmetic_fast_matches_reference(self, rng):
        for b in (2, 2, 5, 17):  # binary twice: the specialized branch
            freqs = rng.integers(1, 500, b)
            code = ArithmeticCode(freqs)
            syms = rng.integers(0, b, 400)
            blob = code.encode(syms)
            got = code.decode(blob, 400)
            ref = code.decode_reference(blob, 400)
            assert np.array_equal(got, syms)
            assert np.array_equal(ref, syms)


class TestPredictCompressedEngines:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_engines_bit_exact_and_match_forest(self, rng, task):
        forest = random_forest(seed=5, n_trees=25, max_depth=9, task=task)
        comp = CompressedForest.from_bytes(
            compress_forest(forest).to_bytes()
        )
        x = rng.integers(0, 16, size=(300, 5))
        fast = predict_compressed(comp, x)
        slow = predict_compressed(comp, x, engine="bitwise")
        assert np.array_equal(fast, slow)  # bit-exact across engines
        # and both equal the uncompressed forest's prediction
        if task == "classification":
            votes = np.zeros((300, 2), np.int64)
            for t in forest.trees:
                for i in range(300):
                    votes[i, int(t.predict_one(x[i]))] += 1
            assert np.array_equal(fast, votes.argmax(1))
        else:
            acc = np.zeros(300)
            for t in forest.trees:
                acc += np.array(
                    [forest.fit_values[int(t.predict_one(x[i]))]
                     for i in range(300)]
                )
            np.testing.assert_allclose(fast, acc / forest.n_trees, rtol=1e-12)

    def test_streamed_trees_equal_across_engines(self):
        forest = random_forest(seed=9, n_trees=10, max_depth=7)
        comp = compress_forest(forest)
        for a, b, orig in zip(
            iter_trees(comp), iter_trees(comp, engine="bitwise"), forest.trees
        ):
            assert a.equals(b)
            assert a.equals(orig)

    def test_unknown_engine_raises(self):
        forest = random_forest(seed=1, n_trees=2, max_depth=3)
        comp = compress_forest(forest)
        with pytest.raises(ValueError):
            list(iter_trees(comp, engine="nope"))


class TestFusedAggregationKernel:
    def _heap_forest(self, rng, t=9, n=150, d=6, depth=5):
        import jax.numpy as jnp

        h = (1 << (depth + 1)) - 1
        feature = rng.integers(0, d, (t, h)).astype(np.int32)
        threshold = rng.integers(0, 16, (t, h)).astype(np.int32)
        is_internal = rng.random((t, h)) < 0.6
        is_internal[:, (h - 1) // 2 :] = False
        xb = rng.integers(0, 16, (n, d)).astype(np.int32)
        return (
            jnp.asarray(xb), jnp.asarray(feature), jnp.asarray(threshold),
            jnp.asarray(is_internal), depth, t, h,
        )

    def test_agg_matches_per_tree_kernel_reduced(self, rng):
        import jax.numpy as jnp

        from repro.kernels.tree_predict.tree_predict import (
            forest_predict,
            forest_predict_agg,
        )

        xb, feat, thr, inter, depth, t, h = self._heap_forest(rng)
        fit = jnp.asarray(rng.normal(size=(t, h)).astype(np.float32))
        per_tree = forest_predict(xb, feat, thr, fit, inter, max_depth=depth)
        agg = forest_predict_agg(xb, feat, thr, fit, inter, max_depth=depth)
        np.testing.assert_allclose(
            np.asarray(agg), np.asarray(per_tree).sum(0),
            rtol=1e-5, atol=1e-5,
        )

    def test_agg_votes_exact(self, rng):
        import jax.numpy as jnp

        from repro.kernels.tree_predict.ref import (
            forest_predict_agg_reference,
        )
        from repro.kernels.tree_predict.tree_predict import forest_predict_agg

        xb, feat, thr, inter, depth, t, h = self._heap_forest(rng)
        fit = jnp.asarray(rng.integers(0, 3, (t, h)).astype(np.float32))
        votes = forest_predict_agg(
            xb, feat, thr, fit, inter, max_depth=depth, n_classes=3
        )
        ref = forest_predict_agg_reference(
            xb, feat, thr, fit, inter, depth, n_classes=3
        )
        np.testing.assert_array_equal(np.asarray(votes), np.asarray(ref))

    def test_f32_precision_guard_at_boundary(self, rng):
        import jax.numpy as jnp

        from repro.kernels.tree_predict.tree_predict import forest_predict

        xb, feat, thr, inter, depth, t, h = self._heap_forest(rng, t=2, n=8)
        fit = jnp.asarray(rng.normal(size=(t, h)).astype(np.float32))
        ok = np.asarray(thr).copy()
        ok[0, 0] = 2**24 - 1  # largest exactly-representable int32 in f32
        forest_predict(
            xb, feat, jnp.asarray(ok), fit, inter, max_depth=depth
        )
        bad = np.asarray(thr).copy()
        bad[0, 0] = 2**24
        with pytest.raises(ValueError, match="2\\*\\*24"):
            forest_predict(
                xb, feat, jnp.asarray(bad), fit, inter, max_depth=depth
            )
        with pytest.raises(ValueError, match="heap nodes"):
            forest_predict(xb, feat, thr, fit, inter, max_depth=30)


class TestServeDriver:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_session_serve_matches_predict_compressed(self, rng, task):
        from repro.serving import ForestServer

        forest = random_forest(seed=13, n_trees=13, max_depth=6, task=task)
        comp = compress_forest(forest)
        x = rng.integers(0, 16, size=(120, 5))
        ref = predict_compressed(comp, x)
        got = ForestServer.from_forest(comp).predict(x, block_trees=5)
        if task == "classification":
            assert np.array_equal(got, ref)  # integer votes: exact
        else:
            # kernel accumulates leaf fits in float32
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

    def test_heap_tiles_roundtrip(self, rng):
        """Heap packing preserves every root-to-leaf decision."""
        from repro.launch.serve_forest import iter_heap_tiles

        forest = random_forest(seed=17, n_trees=6, max_depth=5,
                               task="classification")
        comp = compress_forest(forest)
        tiles = list(iter_heap_tiles(comp, block_trees=4))
        assert sum(f.shape[0] for f, *_ in tiles) == forest.n_trees
        x = rng.integers(0, 16, size=(50, 5))
        k = 0
        for feature, threshold, fit, is_internal in tiles:
            for row in range(feature.shape[0]):
                tree = forest.trees[k]
                for i in range(20):
                    slot = 0
                    while is_internal[row, slot]:
                        if x[i, feature[row, slot]] <= threshold[row, slot]:
                            slot = 2 * slot + 1
                        else:
                            slot = 2 * slot + 2
                    assert fit[row, slot] == float(
                        tree.node_fit[
                            int(_leaf_of(tree, x[i]))
                        ]
                    )
                k += 1


def _leaf_of(tree, x_row) -> int:
    i = 0
    while tree.feature[i] >= 0:
        if x_row[tree.feature[i]] <= tree.threshold[i]:
            i = int(tree.children_left[i])
        else:
            i = int(tree.children_right[i])
    return i

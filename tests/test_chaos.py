"""Fault-tolerance tests (ISSUE 6): integrity-checked framing, the
crash-safe recluster journal, and serving's graceful degradation — all
driven by the deterministic fault-injection harness (``runtime.chaos``).
"""
import io

import numpy as np
import pytest

from repro.core.framing import (
    CRC_MAGIC,
    FramingError,
    IntegrityError,
    TruncatedFrameError,
    check_crc,
    read_arr,
    read_bytes,
    with_crc,
    write_arr,
)
from repro.runtime.chaos import (
    CrashSchedule,
    InjectedCrash,
    TransientError,
    TransientFaults,
    flip_bit,
    flip_bits,
    poison_user,
    truncate,
)
from repro.serving import ForestServer
from repro.store import (
    MigrationJournal,
    build_store,
    encode_user_delta,
    recluster,
    resume_recluster,
)
from repro.store.delta import UserDelta
from repro.store.fleet import make_drifted_fleet, make_synthetic_fleet
from repro.store.lifecycle import RemapTable
from repro.store.runtime import ForestStore

from conftest import random_forest


# ---------------------------------------------------------------------------
# integrity-checked framing
# ---------------------------------------------------------------------------

class TestFramingBounds:
    def test_bytes_length_clamped_against_buffer(self):
        """A corrupted u32 length must not turn into a huge allocation:
        the read is bounds-checked BEFORE any bytes are materialized."""
        import struct

        buf = io.BytesIO(struct.pack("<I", 0xFFFFFFFF) + b"tiny")
        with pytest.raises(TruncatedFrameError, match="claims"):
            read_bytes(buf)

    def test_arr_payload_clamped(self):
        out = io.BytesIO()
        write_arr(out, np.arange(1000, dtype=np.int64))
        data = out.getvalue()[:40]  # cut mid-payload
        with pytest.raises(TruncatedFrameError):
            read_arr(io.BytesIO(data))

    def test_arr_bad_dtype_tag_is_typed(self):
        out = io.BytesIO()
        write_arr(out, np.arange(4, dtype=np.int32))
        data = bytearray(out.getvalue())
        data[1:4] = b"\xff\xfe\xfd"  # clobber the dtype string
        with pytest.raises(IntegrityError, match="dtype"):
            read_arr(io.BytesIO(bytes(data)))

    def test_arr_shape_size_mismatch_is_typed(self):
        out = io.BytesIO()
        write_arr(out, np.arange(6, dtype=np.int32).reshape(2, 3))
        data = bytearray(out.getvalue())
        # the u32 element count sits right after the 1-byte tag length,
        # the tag itself, and the 1-byte ndim
        tag_len = data[0]
        data[tag_len + 2] = 99  # size no longer equals prod(shape)
        with pytest.raises(IntegrityError, match="shape"):
            read_arr(io.BytesIO(bytes(data)))

    def test_crc_roundtrip_and_mismatch(self):
        payload = b"hello framing"
        framed = with_crc(payload)
        assert check_crc(framed) == payload
        assert check_crc(payload) == payload  # CRC-less passthrough
        corrupted = flip_bit(framed, 13)
        with pytest.raises(IntegrityError, match="CRC mismatch"):
            check_crc(corrupted)

    def test_typed_errors_are_valueerrors(self):
        """Pre-existing ``except ValueError`` callers keep working."""
        assert issubclass(FramingError, ValueError)
        assert issubclass(TruncatedFrameError, FramingError)
        assert issubclass(IntegrityError, FramingError)


@pytest.fixture(scope="module")
def tiny_store():
    fleet = make_synthetic_fleet(n_users=3, d=5, n_bins=12, seed=7)
    return build_store(fleet)


class TestFrameIntegrity:
    """Every top-level frame writer emits a CRC trailer; every reader
    verifies it, rejects truncations with typed errors, and still parses
    legacy CRC-less frames."""

    def _frames(self, store):
        delta = store.delta(store.user_ids[0])
        remap = RemapTable(
            old_generation=1, new_generation=2,
            vars_map=np.arange(3, dtype=np.int32),
            splits_map={0: np.arange(2, dtype=np.int32)},
            fits_map=np.arange(2, dtype=np.int32),
        )
        return {
            "RFS1": (store.shared.to_bytes(), type(store.shared).from_bytes),
            "RFD1": (delta.to_bytes(), UserDelta.from_bytes),
            "RFT1": (store.to_bytes(), ForestStore.from_bytes),
            "RFM1": (remap.to_bytes(), RemapTable.from_bytes),
        }

    def test_writers_emit_crc_trailer(self, tiny_store):
        for name, (data, _) in self._frames(tiny_store).items():
            assert data[-8:-4] == CRC_MAGIC, name

    def test_crc_flip_detected(self, tiny_store):
        for name, (data, parse) in self._frames(tiny_store).items():
            bad, _ = flip_bits(data[:-8], seed=3)  # payload corruption
            with pytest.raises(IntegrityError, match="CRC"):
                parse(bad + data[-8:])

    def test_truncation_typed(self, tiny_store):
        for name, (data, parse) in self._frames(tiny_store).items():
            # strip the trailer so the cut exercises the bounds-checked
            # readers rather than the CRC length check
            bare = data[:-8]
            for keep in (4, len(bare) // 2, len(bare) - 1):
                with pytest.raises(FramingError):
                    parse(truncate(bare, keep))

    def test_legacy_crcless_frames_parse(self, tiny_store, monkeypatch):
        """Frames from pre-ISSUE-6 writers (no CRC trailer ANYWHERE,
        nested frames included) must still parse.  Emulated by stubbing
        the trailer out of every serializer — just stripping the outer
        trailer would leave nested deltas' trailers behind, which is not
        what an old writer produced."""
        import repro.store.codebook as cb
        import repro.store.delta as dl
        import repro.store.lifecycle as lc
        import repro.store.runtime as rt

        for mod in (cb, dl, lc, rt):
            monkeypatch.setattr(mod, "with_crc", lambda b: b)
        legacy = self._frames(tiny_store)
        monkeypatch.undo()
        modern = self._frames(tiny_store)
        for name in modern:
            legacy_bytes, parse = legacy[name]
            assert legacy_bytes[-8:-4] != CRC_MAGIC, name
            reparsed = parse(legacy_bytes)
            assert reparsed.to_bytes() == modern[name][0], name

    def test_rft1_zero_codebooks_is_typed(self, tiny_store):
        data = bytearray(check_crc(tiny_store.to_bytes()))
        data[4:6] = b"\x00\x00"  # u16 codebook count -> 0
        with pytest.raises(IntegrityError, match="codebook"):
            # re-seal so the corruption passes the CRC and exercises the
            # structural check itself
            ForestStore.from_bytes(with_crc(bytes(data)))


# ---------------------------------------------------------------------------
# harness determinism
# ---------------------------------------------------------------------------

class TestHarness:
    def test_flip_bits_seed_deterministic(self):
        data = bytes(range(64))
        a, pa = flip_bits(data, seed=5, n=3)
        b, pb = flip_bits(data, seed=5, n=3)
        assert a == b and pa == pb
        c, _ = flip_bits(data, seed=6, n=3)
        assert c != a

    def test_crash_schedule_records_and_fires_once(self):
        sched = CrashSchedule(fail_at=("two",))
        sched("one")
        with pytest.raises(InjectedCrash):
            sched("two")
        sched("two")  # each trigger fires once
        assert sched.steps == ["one", "two", "two"]

    def test_crash_schedule_by_index(self):
        sched = CrashSchedule(fail_at=(1,))
        sched("a")
        with pytest.raises(InjectedCrash):
            sched("b")

    def test_transient_faults_fail_first_n(self):
        faults = TransientFaults(fail_first=2)
        for _ in range(2):
            with pytest.raises(TransientError):
                faults()
        faults()  # third call succeeds
        assert faults.calls == 3


# ---------------------------------------------------------------------------
# crash-safe recluster journal
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def drifted_store_bytes():
    """A drifted fleet store serialized once; each crash point rehydrates
    a fresh copy cheaply via from_bytes instead of re-clustering."""
    initial, late = make_drifted_fleet(
        n_users=5, d=5, n_bins=12, max_depth=4, seed=3
    )
    store = build_store(initial)
    for u, f in late.items():
        store.add_delta(u, encode_user_delta(f, store.shared))
    return store.to_bytes()


@pytest.fixture(scope="module")
def baseline(drifted_store_bytes):
    store = ForestStore.from_bytes(drifted_store_bytes)
    return {u: store.reconstruct(u) for u in store.user_ids}


class TestJournaledRecluster:
    def test_journal_roundtrip(self):
        j = MigrationJournal()
        j.log_built(
            "extend",
            _FakeCodebook(b"CBYTES"),
            _FakeRemap(1, 2, b"RBYTES"),
        )
        j.log_installed()
        j.log_migrate_intent("alice", b"old-delta")
        j.log_migrate_commit("alice", "relabeled")
        j.log_migrate_intent("bob", b"old-delta-2")
        jj = MigrationJournal.from_bytes(j.to_bytes())
        assert jj.state == "installed"
        assert jj.mode == "extend"
        assert (jj.old_generation, jj.new_generation) == (1, 2)
        assert jj.codebook_bytes == b"CBYTES"
        assert jj.entries["alice"]["committed"]
        assert jj.entries["alice"]["status"] == "relabeled"
        assert jj.uncommitted_users == ["bob"]
        assert jj.entries["bob"]["intent"] == b"old-delta-2"

    def test_journal_persists_to_path(self, tmp_path):
        path = str(tmp_path / "migration.journal")
        j = MigrationJournal(path=path)
        j.log_built(
            "extend", _FakeCodebook(b"CB"), _FakeRemap(1, 2, b"RM")
        )
        loaded = MigrationJournal.load(path)
        assert loaded.state == "built"
        assert loaded.path == path

    def test_crash_at_every_step_then_resume_is_bit_exact(
        self, drifted_store_bytes, baseline
    ):
        """THE acceptance test: inject a crash at every journal step of a
        recluster, resume from the journal, and require every user to
        reconstruct bit-exactly with only the successor generation
        resident afterwards."""
        # record the step list with a no-crash run
        sched = CrashSchedule()
        clean = ForestStore.from_bytes(drifted_store_bytes)
        result = recluster(
            clean, mode="extend", journal=MigrationJournal(), on_step=sched
        )
        steps = list(sched.steps)
        assert steps[0] == "build" and steps[-2:] == ["commit", "gc"]
        assert any(s.startswith("migrate:") for s in steps)

        for i, name in enumerate(steps):
            store = ForestStore.from_bytes(drifted_store_bytes)
            journal = MigrationJournal()
            with pytest.raises(InjectedCrash):
                recluster(
                    store, mode="extend", journal=journal,
                    on_step=CrashSchedule(fail_at=(i,)),
                )
            # resume from a SERIALIZED copy: what a restarted process
            # would load from disk
            revived = MigrationJournal.from_bytes(journal.to_bytes())
            if revived.state == "idle":
                r = recluster(store, mode="extend", journal=revived)
            else:
                r = resume_recluster(store, revived)
            assert revived.state == "committed", (i, name)
            assert store.generations == [result.new_generation], (i, name)
            for u, want in baseline.items():
                assert store.reconstruct(u).equals(want), (i, name, u)
            assert r.n_pending == 0, (i, name)

    def test_resume_is_idempotent_after_commit(self, drifted_store_bytes):
        store = ForestStore.from_bytes(drifted_store_bytes)
        journal = MigrationJournal()
        recluster(store, mode="extend", journal=journal)
        before = store.to_bytes()
        r = resume_recluster(store, journal)
        assert store.to_bytes() == before
        assert r.n_pending == 0

    def test_resume_idle_journal_raises(self, drifted_store_bytes):
        store = ForestStore.from_bytes(drifted_store_bytes)
        with pytest.raises(ValueError, match="re-run recluster"):
            resume_recluster(store, MigrationJournal())

    def test_gc_deferred_until_commit(self, drifted_store_bytes):
        """Mid-migration, BOTH generations must stay resident — rollback
        depends on the old codebook surviving until journal commit."""
        store = ForestStore.from_bytes(drifted_store_bytes)
        journal = MigrationJournal()
        with pytest.raises(InjectedCrash):
            recluster(
                store, mode="extend", journal=journal,
                on_step=CrashSchedule(fail_at=("migrated:" + store.user_ids[0],)),
            )
        assert len(store.generations) == 2  # old + new both resident
        resume_recluster(store, journal)
        assert len(store.generations) == 1  # GC ran after commit

    def test_serving_parity_after_crash_recovery(
        self, drifted_store_bytes, baseline, rng
    ):
        """A store recovered mid-migration serves identically to per-user
        ``predict_compressed``."""
        store = ForestStore.from_bytes(drifted_store_bytes)
        journal = MigrationJournal()
        users = store.user_ids
        with pytest.raises(InjectedCrash):
            recluster(
                store, mode="extend", journal=journal,
                on_step=CrashSchedule(fail_at=("migrate:" + users[2],)),
            )
        resume_recluster(store, journal)
        server = ForestServer(store)
        reqs = [
            (u, rng.integers(0, 12, (9, 5)).astype(np.int32))
            for u in users
        ]
        for (u, x), p in zip(reqs, server.serve(reqs)):
            assert np.array_equal(p, store.predict(u, x))


class _FakeCodebook:
    def __init__(self, b):
        self._b = b

    def to_bytes(self):
        return self._b


class _FakeRemap:
    def __init__(self, old, new, b):
        self.old_generation = old
        self.new_generation = new
        self._b = b

    def to_bytes(self):
        return self._b


# ---------------------------------------------------------------------------
# graceful degradation in serving
# ---------------------------------------------------------------------------

@pytest.fixture
def fleet_server(rng):
    fleet = make_synthetic_fleet(n_users=4, d=5, n_bins=12, seed=11)
    store = build_store(fleet)
    server = ForestServer(store, interpret=True, retry_backoff_s=0.0)
    reqs = [
        (u, rng.integers(0, 12, (8, 5)).astype(np.int32))
        for u in store.user_ids
    ]
    return store, server, reqs


class TestGracefulDegradation:
    def test_quarantine_isolates_bad_user(self, fleet_server):
        store, server, reqs = fleet_server
        want = server.serve(reqs)
        bad = store.user_ids[1]
        poison_user(store, bad)
        statuses = server.serve_safe(reqs)
        assert [s.user_id for s in statuses] == [u for u, _ in reqs]
        for (u, _), s, w in zip(reqs, statuses, want):
            if u == bad:
                assert s.status == "quarantined"
                assert s.prediction is None
                assert "IntegrityError" in s.detail
            else:
                assert s.status == "ok"
                assert np.array_equal(s.prediction, w)

    def test_quarantine_sticky_and_counted_once_per_probe(
        self, fleet_server
    ):
        store, server, reqs = fleet_server
        poison_user(store, store.user_ids[0])
        server.serve_safe(reqs)
        n = server.integrity_failures
        server.serve_safe(reqs)  # quarantined: not re-probed
        assert server.integrity_failures == n
        assert server.quarantined_users == [store.user_ids[0]]

    def test_quarantine_released_on_reregistration(self, fleet_server, rng):
        store, server, reqs = fleet_server
        bad = store.user_ids[0]
        repaired = store.delta(bad)  # the healthy delta, kept aside
        poison_user(store, bad)
        assert server.serve_safe(reqs)[0].status == "quarantined"
        store.add_delta(bad, repaired)  # repair bumps the user version
        statuses = server.serve_safe(reqs)
        assert statuses[0].status == "ok"
        assert server.quarantined_users == []
        assert np.array_equal(
            statuses[0].prediction, store.predict(bad, reqs[0][1])
        )

    def test_health_stats(self, fleet_server):
        store, server, reqs = fleet_server
        poison_user(store, store.user_ids[2])
        server.serve_safe(reqs)
        h = server.stats()["health"]
        assert h["n_quarantined"] == 1
        assert h["integrity_failures"] == 1
        assert store.user_ids[2] in h["quarantined"]
        assert h["quarantined"][store.user_ids[2]]["kind"] == "integrity"
        # drift accounting EXCLUDES the quarantined user instead of
        # mislabeling it as a fallback user
        drift = server.stats()["store"]
        assert drift["n_excluded_users"] == 1
        assert drift["n_users"] == len(store.user_ids) - 1

    def test_transient_admission_retry_then_success(self, fleet_server):
        store, server, reqs = fleet_server
        for u in store.user_ids:
            store.arena.invalidate(u)
        store.arena.admission_fault = TransientFaults(fail_first=2)
        statuses = server.serve_safe(reqs, engine="pipelined")
        assert server.transient_retries == 2
        assert server.degraded_batches == 0
        assert all(s.status == "ok" and not s.degraded for s in statuses)

    def test_retries_exhausted_degrades_to_simple(self, fleet_server):
        store, server, reqs = fleet_server
        want = server.serve(reqs, engine="simple")
        for u in store.user_ids:
            store.arena.invalidate(u)
        store.arena.admission_fault = TransientFaults(fail_first=10**6)
        statuses = server.serve_safe(reqs, engine="pipelined")
        assert server.degraded_batches == 1
        assert all(s.status == "ok" and s.degraded for s in statuses)
        for s, w in zip(statuses, want):
            assert np.array_equal(s.prediction, w)

    def test_serve_safe_empty_batch(self, fleet_server):
        _, server, _ = fleet_server
        assert server.serve_safe([]) == []

    def test_unknown_user_still_raises(self, fleet_server):
        _, server, _ = fleet_server
        with pytest.raises(KeyError):
            server.serve_safe([("nobody", np.zeros((1, 5), np.int32))])


# ---------------------------------------------------------------------------
# residency tiers (ISSUE 10): crashes mid-demotion and faults behind prefetch
# ---------------------------------------------------------------------------

class TestResidencyChaos:
    def _fleet_on_disk(self, tmp_path, n_users=6):
        import shutil

        from repro.store import DurableStore

        store0 = build_store(make_synthetic_fleet(
            n_users=n_users, d=5, n_bins=12, seed=13,
            n_trees=(3, 5), max_depth=3,
        ))
        rng = np.random.default_rng(5)
        x = rng.integers(0, 12, (6, 5)).astype(np.int32)
        oracle = {u: store0.predict(u, x) for u in store0.user_ids}
        base = str(tmp_path / "fleet")
        DurableStore.create(base, store0, slab_shards=3)
        snap = str(tmp_path / "snap")
        shutil.copytree(base, snap)
        return store0, base, snap, x, oracle

    def test_demote_writeback_crash_at_every_step(self, tmp_path):
        """Kill the dirty-demotion writeback at EVERY commit step: the
        fleet must recover bit-exact whichever side of the manifest swap
        the crash lands on (re-registered model == same artifact, so pre
        and post states decode identically — a torn state would not)."""
        import shutil

        from repro.runtime.chaos import record_steps
        from repro.store import DurableStore, attach_residency

        store0, base, snap, x, oracle = self._fleet_on_disk(tmp_path)
        victim = store0.user_ids[0]
        victim_bytes = store0._deltas[victim].to_bytes()

        def op(on_step):
            durable = DurableStore.open(base)
            store = durable.load_store(lazy=True)
            mgr = attach_residency(
                store, durable, budget_bytes=10**9, on_step=on_step
            )
            # user_version bump -> dirty -> demotion must write back
            store.add_delta(victim, UserDelta.from_bytes(victim_bytes))
            assert mgr.demote(victim)
            # reload through the placeholder is bit-exact post-writeback
            assert np.array_equal(store.predict(victim, x), oracle[victim])

        steps = record_steps(op)
        assert steps, "writeback produced no commit steps"
        assert steps[-2:] == ["manifest", "gc"]
        for i, name in enumerate(steps):
            shutil.rmtree(base)
            shutil.copytree(snap, base)
            with pytest.raises(InjectedCrash):
                op(CrashSchedule(fail_at=(i,)))
            recovered = DurableStore.open(base).load_store(lazy=False)
            assert sorted(recovered.user_ids) == sorted(oracle)
            for u, want in oracle.items():
                assert np.array_equal(recovered.predict(u, x), want), (
                    i, name, u,
                )

    def test_prefetch_behind_corrupt_shard_never_silent(self, tmp_path):
        """A corrupt shard behind a prefetch: the warm fails typed (cold
        user stays cold, error counted), the serve path raises a typed
        IntegrityError — and after parity repair the SAME placeholder
        reloads bit-exactly.  At no point does a wrong prediction leak."""
        from repro.runtime.chaos import DiskFaults
        from repro.store import DurableStore, Prefetcher, attach_residency
        from repro.store.durable import _LazyShard

        store0, base, snap, x, oracle = self._fleet_on_disk(tmp_path)
        victim = store0.user_ids[0]
        durable = DurableStore.open(base)
        store = durable.load_store(lazy=True)
        mgr = attach_residency(store, durable, budget_bytes=10**9)
        pf = Prefetcher(mgr, background=False)
        entry = durable.shard_for_user(victim)
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 16))

        pf.request([victim])
        mgr.absorb_staged()
        st = mgr.stats()
        assert st["prefetch_errors"] == 1 and st["prefetch_staged"] == 0
        assert isinstance(dict.get(store._deltas, victim), _LazyShard)
        with pytest.raises(IntegrityError):
            store.predict(victim, x)  # typed, never silent wrong
        # parity repair rewrites the shard; the untouched placeholder now
        # warms and serves bit-exactly through the same prefetch path
        assert durable.read_shard(entry.shard_id, repair=True)
        assert pf.request([victim]) == 1
        assert mgr.absorb_staged() == 1
        assert np.array_equal(store.predict(victim, x), oracle[victim])
        assert mgr.stats()["prefetch_hits"] == 1
        pf.close()

    def test_streaming_build_crash_leaves_whole_waves(self, tmp_path):
        """Kill the streaming build at every commit step of every wave:
        recovery always yields a UNION OF COMPLETE WAVES (each bit-exact),
        never a torn wave."""
        import shutil

        from repro.runtime.chaos import record_steps
        from repro.store import DurableStore, build_store_streaming

        fleet = make_synthetic_fleet(
            n_users=6, d=5, n_bins=12, seed=13, n_trees=(3, 5), max_depth=3,
        )
        ref = build_store(fleet)
        rng = np.random.default_rng(5)
        x = rng.integers(0, 12, (6, 5)).astype(np.int32)
        oracle = {u: ref.predict(u, x) for u in ref.user_ids}
        base = str(tmp_path / "stream")
        waves: list[list[str]] = []

        def op(on_step):
            if waves:
                waves.clear()
            shutil.rmtree(base, ignore_errors=True)
            seen: set[str] = set()

            def on_wave(info):
                nonlocal seen
                # membership reconstructed from the durable store itself
                now = set(DurableStore.open(base).load_store(lazy=True)
                          .user_ids)
                waves.append(sorted(now - seen))
                seen = now

            build_store_streaming(
                fleet, base, wave_users=3, k_max=4, seed=0,
                slab_shards=3, on_wave=on_wave, on_step=on_step,
            )

        steps = record_steps(op)
        assert len(waves) == 2 and all(len(w) == 3 for w in waves)
        prefixes = [set()]
        for w in waves:
            prefixes.append(prefixes[-1] | set(w))
        for i in range(len(steps)):
            with pytest.raises(InjectedCrash):
                op(CrashSchedule(fail_at=(i,)))
            d = DurableStore.open(base)
            try:
                recovered = d.load_store(lazy=False)
                got = set(recovered.user_ids)
            except IntegrityError:
                # wave 0 never committed: recovery is the valid EMPTY
                # epoch-0 store (no codebook yet), typed — not torn
                assert d.manifest.epoch == 0, (i, steps[i])
                got = set()
            assert got in prefixes, (i, steps[i], got)
            for u in got:
                assert np.array_equal(recovered.predict(u, x), oracle[u])

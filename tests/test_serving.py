"""ISSUE 4: unified serving session API — plan/execute IR, cross-batch
plan cache (+ invalidation), deprecated shim parity, lossy store wiring,
session stats."""
import numpy as np
import pytest

from conftest import random_forest
from repro.core.forest_codec import compress_forest
from repro.core.compressed_predict import predict_compressed
from repro.core.lossy import LossyConfig
from repro.serving import ForestServer
from repro.store import build_store, make_synthetic_fleet


def small_fleet(task="classification", n_users=6, seed=0):
    return make_synthetic_fleet(
        n_users, task=task, n_trees=(4, 8), max_depth=4, seed=seed
    )


def fleet_requests(store, rng, n_requests=6, rows=20):
    users = store.user_ids
    d = store.shared.n_features
    return [
        (users[i % len(users)], rng.integers(0, 12, (rows, d)).astype(np.int32))
        for i in range(n_requests)
    ]


def assert_matches_store(store, requests, preds, task):
    for (u, x), p in zip(requests, preds):
        ref = store.predict(u, x)
        if task == "classification":
            assert np.array_equal(p, ref)
        else:
            np.testing.assert_allclose(p, ref, rtol=1e-5, atol=1e-5)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


class TestPlanIR:
    def test_plan_fields_and_signature(self, rng):
        store = build_store(small_fleet(n_users=4))
        server = ForestServer(store)
        u = store.user_ids
        requests = [(u[1], rng.integers(0, 12, (9, 8)).astype(np.int32)),
                    (u[0], rng.integers(0, 12, (5, 8)).astype(np.int32)),
                    (u[1], rng.integers(0, 12, (3, 8)).astype(np.int32))]
        plan = server.plan(requests)
        assert plan.users == (u[1], u[0])  # first-appearance order
        assert plan.row_counts == (9, 5, 3)
        assert plan.n_rows == 17
        assert plan.row_slices == (slice(0, 9), slice(9, 14), slice(14, 17))
        assert plan.engine.name in ("simple", "pipelined", "sharded")
        assert plan.engine.reason
        assert plan.t_pad % plan.engine.block_trees == 0
        hash(plan.signature)  # plans are hashable by their signature

    def test_plan_from_row_counts_only(self, rng):
        """Plans depend only on the batch signature — they can be built
        from (user, n_rows) pairs without any row data."""
        store = build_store(small_fleet(n_users=3))
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (7, 8)).astype(np.int32)
        p1 = server.plan([(u[0], x), (u[1], x)])
        p2 = server.plan([(u[0], 7), (u[1], 7)])
        assert p1 is p2  # memoized: identical signatures share the plan

    def test_plan_memoized_until_store_changes(self, rng):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 3)
        p1 = server.plan(reqs)
        p2 = server.plan(reqs)
        assert p1 is p2
        assert server.plan_cache.plan_hits == 1
        store.add_user(store.user_ids[0], fleet[store.user_ids[0]])
        p3 = server.plan(reqs)
        assert p3 is not p1  # registry changed: plan rebuilt
        assert server.plan_cache.invalidations >= 1


class TestEngineChoice:
    def test_cost_model_simple_when_no_arena(self, rng):
        store = build_store(small_fleet(n_users=3))
        store.arena = None  # schema-incompatible store
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 3)
        plan = server.plan(reqs)
        assert plan.engine.name == "simple"
        preds = server.execute(plan, [x for _, x in reqs])
        assert_matches_store(store, reqs, preds, "classification")

    def test_forced_engine_without_arena_raises(self, rng):
        store = build_store(small_fleet(n_users=2))
        store.arena = None
        server = ForestServer(store)
        with pytest.raises(ValueError, match="fused tile arena"):
            server.plan(fleet_requests(store, rng, 2), engine="pipelined")

    def test_unknown_engine_raises(self, rng):
        store = build_store(small_fleet(n_users=2))
        server = ForestServer(store)
        with pytest.raises(ValueError, match="engine"):
            server.plan(fleet_requests(store, rng, 2), engine="nope")

    def test_estimate_shard_speedup(self):
        from repro.kernels.tree_predict.ops import estimate_shard_speedup

        # one dominant user: sharding buys ~nothing
        assert estimate_shard_speedup(np.array([100, 1, 1]), 2) < 1.1
        # even users split perfectly
        assert estimate_shard_speedup(np.array([10, 10, 10, 10]), 2) == 2.0
        assert estimate_shard_speedup(np.zeros(0, np.int64), 4) == 1.0


class TestSessionServing:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    @pytest.mark.parametrize("engine", ["simple", "pipelined", "sharded"])
    def test_engines_match_per_user_predict(self, rng, task, engine):
        store = build_store(small_fleet(task, n_users=5))
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 7, rows=15)
        preds = server.serve(reqs, engine=engine)
        assert_matches_store(store, reqs, preds, task)

    def test_pack_cache_reused_for_fresh_rows(self, rng):
        """Same user-run signature, DIFFERENT row values: the gathered
        pack is reused but predictions follow the new rows."""
        store = build_store(small_fleet(n_users=4))
        server = ForestServer(store)
        u = store.user_ids
        reqs1 = [(u[0], rng.integers(0, 12, (11, 8)).astype(np.int32)),
                 (u[2], rng.integers(0, 12, (6, 8)).astype(np.int32))]
        server.serve(reqs1)
        reqs2 = [(u[0], rng.integers(0, 12, (11, 8)).astype(np.int32)),
                 (u[2], rng.integers(0, 12, (6, 8)).astype(np.int32))]
        preds = server.serve(reqs2)
        assert server.plan_cache.pack_hits >= 1
        assert_matches_store(store, reqs2, preds, "classification")

    def test_empty_and_zero_row_requests(self, rng):
        store = build_store(small_fleet(n_users=3))
        server = ForestServer(store)
        assert server.serve([]) == []
        u = store.user_ids
        x = rng.integers(0, 12, (10, 8)).astype(np.int32)
        empty = np.zeros((0, 8), np.int32)
        preds = server.serve([(u[0], x), (u[1], empty), (u[2], x)])
        assert preds[1].shape == (0,)
        assert np.array_equal(preds[0], store.predict(u[0], x))
        assert np.array_equal(preds[2], store.predict(u[2], x))

    def test_execute_validates_rows_against_plan(self, rng):
        store = build_store(small_fleet(n_users=2))
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (8, 8)).astype(np.int32)
        plan = server.plan([(u[0], x)])
        with pytest.raises(ValueError, match="rows"):
            server.execute(plan, [x[:5]])
        with pytest.raises(ValueError, match="requests"):
            server.execute(plan, [x, x])

    def test_stale_plan_rejected_after_reregistration(self, rng):
        fleet = small_fleet(n_users=2)
        store = build_store(fleet)
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (8, 8)).astype(np.int32)
        plan = server.plan([(u[0], x)])
        store.add_user(u[0], fleet[u[0]])
        with pytest.raises(ValueError, match="stale"):
            server.execute(plan, [x])


class TestPlanCacheInvalidation:
    def test_arena_eviction_invalidates_cached_pack(self, rng):
        """A cached plan/pack must be invalidated (not served stale) after
        an arena eviction touching its users."""
        store = build_store(small_fleet(n_users=4))
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (9, 8)).astype(np.int32)
        reqs = [(u[0], x), (u[1], x)]
        server.serve(reqs)
        epoch0 = store.arena.epoch
        store.arena.invalidate(u[0])  # eviction: epoch bumps
        assert store.arena.epoch > epoch0
        preds = server.serve(reqs)  # must re-gather, not reuse
        assert server.plan_cache.invalidations >= 1
        assert_matches_store(store, reqs, preds, "classification")

    def test_cold_admission_of_unrelated_users_keeps_pack(self, rng):
        """Partial invalidation (ISSUE 5): admitting a DIFFERENT user set
        no longer sweeps the whole pack cache — the original batch's pack
        survives (its users' run tokens are unchanged) and still serves
        correctly."""
        store = build_store(small_fleet(n_users=6))
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (7, 8)).astype(np.int32)
        reqs_a = [(u[0], x), (u[1], x)]
        server.serve(reqs_a)
        misses0 = server.plan_cache.pack_misses
        hits0 = server.plan_cache.pack_hits
        server.serve([(u[4], x), (u[5], x)])  # unrelated cold admissions
        preds = server.serve(reqs_a)
        # one miss for the new batch, then a HIT for the untouched one
        assert server.plan_cache.pack_misses == misses0 + 1
        assert server.plan_cache.pack_hits == hits0 + 1
        assert_matches_store(store, reqs_a, preds, "classification")

    def test_eviction_invalidates_only_affected_users_packs(self, rng):
        """Evicting one user's arena run drops only the packs containing
        that user; a disjoint batch's pack keeps hitting."""
        store = build_store(small_fleet(n_users=6))
        server = ForestServer(store)
        u = store.user_ids
        x = rng.integers(0, 12, (7, 8)).astype(np.int32)
        reqs_a = [(u[0], x), (u[1], x)]
        reqs_b = [(u[2], x), (u[3], x)]
        server.serve(reqs_a)
        server.serve(reqs_b)
        inval0 = server.plan_cache.invalidations
        hits0 = server.plan_cache.pack_hits
        store.arena.invalidate(u[0])  # eviction touching only reqs_a
        preds_b = server.serve(reqs_b)  # untouched: pack HIT
        preds_a = server.serve(reqs_a)  # touched: re-gathered
        assert server.plan_cache.pack_hits == hits0 + 1
        assert server.plan_cache.invalidations == inval0 + 1
        assert_matches_store(store, reqs_a, preds_a, "classification")
        assert_matches_store(store, reqs_b, preds_b, "classification")

    def test_reregistration_serves_new_forest(self, rng):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        server = ForestServer(store)
        u0 = store.user_ids[0]
        x = rng.integers(0, 12, (25, 8)).astype(np.int32)
        server.serve([(u0, x)])
        new_forest = small_fleet(n_users=3, seed=9)[
            list(small_fleet(n_users=3, seed=9))[0]
        ]
        store.add_user(u0, new_forest)
        preds = server.serve([(u0, x)])
        assert np.array_equal(preds[0], store.predict(u0, x))

    def test_pack_hits_on_repeated_batch(self, rng):
        store = build_store(small_fleet(n_users=4))
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 4)
        for _ in range(3):
            server.serve(reqs)
        stats = server.plan_cache.stats()
        assert stats["pack_hits"] >= 2
        assert stats["plan_hits"] >= 2
        assert stats["pack_hit_rate"] > 0


class TestShimsRemoved:
    def test_legacy_entry_points_are_gone(self):
        """The PR 4 deprecation shims' removal timeline has elapsed — the
        names must no longer exist (stale callers should fail loudly at
        import, not silently re-grow a second serving path)."""
        from repro.launch import serve_forest, serve_store

        assert not hasattr(serve_forest, "serve_compressed_forest")
        assert not hasattr(serve_store, "serve_store_batch")


class TestSingleForestSession:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_from_forest_matches_predict_compressed(self, rng, task):
        forest = random_forest(seed=2, n_trees=10, max_depth=6, task=task)
        comp = compress_forest(forest)
        server = ForestServer.from_forest(comp)
        x = rng.integers(0, 16, (50, 5)).astype(np.int32)
        got = server.predict(x)
        ref = predict_compressed(comp, x)
        if task == "classification":
            assert np.array_equal(got, ref)
        else:
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

    def test_from_plain_forest_and_registry_guard(self, rng):
        forest = random_forest(seed=4, n_trees=6, max_depth=4)
        server = ForestServer.from_forest(forest, user_id="me")
        x = rng.integers(0, 16, (12, 5)).astype(np.int32)
        comp = compress_forest(forest)
        assert np.array_equal(server.predict(x), predict_compressed(comp, x))
        assert server.store.user_ids == ["me"]
        with pytest.raises(KeyError):
            server.store.n_trees("someone-else")
        with pytest.raises(TypeError):
            server.store.add_user("x", forest)


class TestLossyStore:
    def test_fleet_grid_quantization_and_bounds(self, rng):
        fleet = small_fleet("regression", n_users=5)
        bits = 5
        store = build_store(fleet, lossy=LossyConfig(fit_bits=bits))
        rep = store.size_report()["lossy"]
        assert rep["fit_bits"] == bits
        assert rep["grid_levels"] == 1 << bits
        # the fleet table IS the learned fixed-rate grid
        assert len(store.shared.fleet_fit_values) <= 1 << bits
        # measured error within the closed-form §6 bound
        assert rep["max_abs_error"] <= rep["max_error_bound"] + 1e-12
        assert rep["distortion_bound"] == pytest.approx(
            rep["step"] ** 2 / 12.0
        )
        # quantized store still serves (losslessly w.r.t. its own grid)
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 3)
        preds = server.serve(reqs)
        assert_matches_store(store, reqs, preds, "regression")
        assert server.stats()["lossy"] == rep

    def test_lossy_shrinks_fit_table_vs_lossless(self):
        fleet = small_fleet("regression", n_users=5)
        lossless = build_store(fleet)
        lossy = build_store(fleet, lossy=LossyConfig(fit_bits=4))
        assert (
            len(lossy.shared.fleet_fit_values)
            < len(lossless.shared.fleet_fit_values)
        )
        assert lossless.size_report()["lossy"] is None

    def test_classification_fleet_rejected(self):
        with pytest.raises(ValueError, match="regression"):
            build_store(small_fleet(n_users=2), lossy=LossyConfig(4))


class TestStatsAndPack:
    def test_server_stats_aggregate(self, rng):
        store = build_store(small_fleet(n_users=4))
        server = ForestServer(store)
        reqs = fleet_requests(store, rng, 4)
        server.serve(reqs)
        server.serve(reqs)
        stats = server.stats()
        assert set(stats) == {
            "engine_counts", "engine_timings", "plan_cache", "tile_cache",
            "arena", "store", "lossy", "residency", "health",
        }
        # ISSUE 10: no residency manager attached -> explicit None
        assert stats["residency"] is None
        assert sum(stats["engine_counts"].values()) == 2
        for name, t in stats["engine_timings"].items():
            assert name in stats["engine_counts"]
            assert t["count"] == stats["engine_counts"][name]
            assert t["p99_ms"] >= t["p50_ms"] >= 0
        assert stats["plan_cache"]["pack_hit_rate"] > 0
        assert stats["arena"]["resident_users"] > 0
        assert "per_user" in stats["tile_cache"]
        # ISSUE 5: drift is observable without reaching into the store
        assert stats["store"]["codebook_generation"] == 1
        assert stats["store"]["fallback_user_fraction"] == 0.0
        # ISSUE 6: fault-tolerance counters, all quiet on a healthy fleet
        health = stats["health"]
        assert health["n_quarantined"] == 0
        assert health["integrity_failures"] == 0
        assert health["degraded_batches"] == 0
        assert health["journal"] is None

    def test_tile_cache_per_user_counters_reset_on_reregistration(self, rng):
        # ISSUE 10 bugfix: a user's hit/miss counters describe ONE
        # registered model; re-registration (user_version bump) must
        # reset them or the stale ratio poisons admission decisions.
        store = build_store(small_fleet(n_users=3))
        server = ForestServer(store)
        user = store.user_ids[0]
        reqs = fleet_requests(store, rng, 3)
        reqs = [(user, reqs[0][1])] + reqs
        server.serve(reqs)
        server.serve(reqs)
        before = store.cache.stats()["per_user"][user]
        assert before["hits"] + before["misses"] > 0
        store.add_delta(user, store._deltas[user])  # re-register
        per_user = store.cache.stats()["per_user"]
        assert user not in per_user  # counters reset with the tiles
        # demotion-style invalidation (reset_stats=False) keeps them:
        # same model will reload bit-exactly, the ratio stays meaningful
        server.serve(reqs)
        assert store.cache.stats()["per_user"][user]["misses"] > 0
        kept = store.cache.stats()["per_user"][user]
        store.cache.invalidate_user(user, reset_stats=False)
        assert store.cache.stats()["per_user"][user] == kept

    def test_canonical_pad_helper(self):
        from repro.launch.serve_store import _pad_heap_width
        from repro.serving.pack import pad_heap_width

        assert _pad_heap_width is pad_heap_width  # ONE implementation
        a = np.arange(6, dtype=np.int32).reshape(2, 3)
        assert pad_heap_width(a, 3) is a  # width match: no copy
        out = pad_heap_width(a, 5)
        assert out.shape == (2, 5)
        assert np.array_equal(out[:, :3], a) and not out[:, 3:].any()
        with pytest.raises(ValueError, match="shrink"):
            pad_heap_width(a, 2)

    def test_arena_epoch_tracks_structural_changes(self, rng):
        store = build_store(small_fleet(n_users=3))
        server = ForestServer(store)
        arena = store.arena
        e0 = arena.epoch
        server.serve(fleet_requests(store, rng, 2))  # admissions
        e1 = arena.epoch
        assert e1 > e0
        server.serve(fleet_requests(store, rng, 2))  # warm: no change
        assert arena.epoch == e1

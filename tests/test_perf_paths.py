"""Parity tests for the optimized compute paths added in the §Perf loop:
chunked WKV6, paired-causal blockwise attention, EP MoE dispatch, and the
wire-quantized gradient sync."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.rwkv6 import wkv_chunked, wkv_scan


class TestChunkedWKV:
    def _inputs(self, seed, B=2, S=64, H=2, hd=8, lw_hi=1.0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 6)
        r = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        logw = jax.random.uniform(ks[3], (B, S, H, hd), minval=-6.0,
                                  maxval=lw_hi)
        w = jnp.exp(-jnp.exp(logw))
        u = jax.random.normal(ks[4], (H, hd)) * 0.1
        s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
        return r, k, v, w, u, s0

    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_matches_scan(self, chunk):
        r, k, v, w, u, s0 = self._inputs(0)
        y1, st1 = wkv_scan(r, k, v, w, u, s0)
        y2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st1), np.asarray(st2),
                                   rtol=1e-4, atol=1e-4)

    def test_extreme_decay_stable(self):
        """The pairwise-difference form must not overflow for any decay."""
        r, k, v, w, u, s0 = self._inputs(1, lw_hi=2.5)
        y2, st2 = wkv_chunked(r, k, v, w, u, s0, chunk=16)
        assert not bool(jnp.isnan(y2).any() | jnp.isinf(y2).any())
        y1, _ = wkv_scan(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-3, atol=1e-3)

    def test_gradients_match(self):
        r, k, v, w, u, s0 = self._inputs(2)
        g1 = jax.grad(lambda r_: wkv_scan(r_, k, v, w, u, s0)[0].sum())(r)
        g2 = jax.grad(
            lambda r_: wkv_chunked(r_, k, v, w, u, s0, chunk=16)[0].sum()
        )(r)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), chunk=st.sampled_from([8, 16]))
    def test_property_parity(self, seed, chunk):
        r, k, v, w, u, s0 = self._inputs(seed, B=1, S=32, H=1, hd=4)
        y1, _ = wkv_scan(r, k, v, w, u, s0)
        y2, _ = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)


class TestPairedCausal:
    def _qkv(self, seed, B=2, S=256, H=4, KV=2, hd=16):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        return q, k, v

    def _dense(self, q, k, v):
        from repro.models.attention import _sdpa

        s = q.shape[1]
        idx = jnp.arange(s)
        mask = (idx[:, None] >= idx[None, :])[None, None]
        return _sdpa(q, k, v, mask, q.shape[2] // k.shape[2])

    @pytest.mark.parametrize("chunk", [32, 64])
    def test_matches_dense(self, chunk):
        from repro.models.blockwise import _paired_causal

        q, k, v = self._qkv(0)
        ref = self._dense(q, k, v)
        out = _paired_causal(q, k, v, chunk=chunk, scale=16**-0.5)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)

    def test_dispatcher_uses_paired_for_plain_causal(self):
        from repro.models.blockwise import chunked_attention

        q, k, v = self._qkv(1)
        ref = self._dense(q, k, v)
        out = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)

    def test_window_falls_back_to_table(self):
        from repro.models.blockwise import chunked_attention
        from repro.models.attention import _sdpa

        q, k, v = self._qkv(2)
        s = q.shape[1]
        idx = jnp.arange(s)
        w = 96
        mask = ((idx[:, None] >= idx[None, :])
                & (idx[:, None] - idx[None, :] < w))[None, None]
        ref = _sdpa(q, k, v, mask, 2)
        out = chunked_attention(q, k, v, causal=True, window=w,
                                q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-5, atol=1e-5)


class TestWireQuantizedPsum:
    def test_unbiased_and_bounded(self):
        """Dithered 4-bit codes: the decoded mean tracks the true mean
        within one quantization step (single-device psum)."""
        from repro.optim.compression import wire_quantized_psum

        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(512,)), jnp.float32)}

        def f(x):
            return wire_quantized_psum(
                {"w": x}, "d", bits=4, key=jax.random.PRNGKey(1), n_ranks=1
            )["w"]

        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((1,), ("d",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        out = jax.jit(
            jax.shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                          check_vma=False)
        )(g["w"])
        step = float(jnp.abs(g["w"]).max()) / 7
        assert float(jnp.abs(out - g["w"]).max()) <= step


def test_ep_moe_matches_dense_reference():
    """Covered in-depth under the fake-device dry-run; here: the dense
    path itself stays the oracle on a single device."""
    from repro.models.moe import _moe_apply_dense, init_moe, moe_apply

    cfg = get_config("granite-moe-3b-a800m").smoke()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    out1, aux1 = moe_apply(p, cfg, x)  # no mesh -> dense
    out2, aux2 = _moe_apply_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
    assert float(aux1) == float(aux2)

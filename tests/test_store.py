"""Tier-1 tests for the multi-tenant forest store (ISSUE 2):

* fleet-scale Bregman clustering edge cases (K=1, K >= M, empty-cluster
  re-seeding, chunked-vs-dense assignment parity);
* shared codebook build + byte roundtrip;
* per-user delta encode -> decode bit-exactness (both tasks), hydration
  parity with the inline codec's predictions, and storage-size wins over
  independent per-forest compression;
* late onboarding against a frozen codebook (user-local clusters);
* the tile LRU cache (hits, eviction, invalidation);
* the segment-aware aggregation kernel vs its oracle;
* ragged multi-tenant serving vs per-user predict_compressed.

No importorskip: everything here runs on the baked-in numpy + jax stack.
"""
import numpy as np
import pytest

from repro.core import CompressedForest, compress_forest, predict_compressed
from repro.core.bregman import cluster_models, kl_assign, kl_kmeans
from repro.core.tree import Forest, ForestMeta, Tree
from repro.store import (
    ForestStore,
    SharedCodebook,
    TileCache,
    UserDelta,
    build_shared_codebook,
    build_store,
    encode_user_delta,
    hydrate,
    make_synthetic_fleet,
    reconstruct_user,
)

from conftest import random_forest


def small_fleet(task="classification", n_users=8, seed=0):
    return make_synthetic_fleet(
        n_users, task=task, n_trees=(5, 9), d=5, n_bins=12, max_depth=5,
        seed=seed,
    )


class TestBregmanEdgeCases:
    def test_k_equals_one(self, rng):
        counts = rng.integers(0, 40, (30, 6)).astype(float)
        for engine in ("dense", "chunked"):
            assign, cent, obj = kl_kmeans(counts, 1, engine=engine)
            assert np.all(assign == 0)
            assert cent.shape == (1, 6)
            assert obj >= 0

    def test_k_at_least_m(self, rng):
        counts = rng.integers(1, 40, (4, 6)).astype(float)
        for engine in ("dense", "chunked"):
            assign, cent, obj = kl_kmeans(counts, 10, engine=engine)
            assert cent.shape[0] == 4  # k clamped to M
            # every model gets (numerically) its own centroid: loss ~ 0
            # (the dense engine accumulates in float32 under jit)
            assert obj < 1e-3

    def test_empty_cluster_reseeding(self):
        # 3 well-separated groups but MANY duplicate rows: naive Lloyd with
        # k=4 empties a cluster; the chunked engine must re-seed it
        # deterministically and still converge to <= 3 used clusters that
        # cover the data.
        a = np.tile([100, 1, 1], (10, 1))
        b = np.tile([1, 100, 1], (10, 1))
        c = np.tile([1, 1, 100], (10, 1))
        counts = np.concatenate([a, b, c]).astype(float)
        assign1, cent1, obj1 = kl_kmeans(counts, 4, engine="chunked", seed=0)
        assign2, cent2, obj2 = kl_kmeans(counts, 4, engine="chunked", seed=0)
        assert np.array_equal(assign1, assign2)  # deterministic
        assert obj1 == obj2
        # the three groups must land in three distinct clusters
        groups = [np.unique(assign1[i * 10 : (i + 1) * 10]) for i in range(3)]
        assert all(len(g) == 1 for g in groups)
        assert len({int(g[0]) for g in groups}) == 3

    def test_chunked_vs_dense_assignment_parity(self, rng):
        counts = rng.integers(0, 100, (257, 9)).astype(float)
        centroids = rng.dirichlet(np.ones(9), size=7)
        a_dense, d_dense = kl_assign(counts, centroids, chunk_size=None)
        for chunk in (1, 13, 64, 10_000):
            a_chunk, d_chunk = kl_assign(counts, centroids, chunk_size=chunk)
            assert np.array_equal(a_dense, a_chunk)
            # BLAS reduction order varies with chunk shape: ~ulp agreement
            np.testing.assert_allclose(d_dense, d_chunk, rtol=1e-12)

    def test_chunked_kmeans_chunk_size_invariant(self, rng):
        counts = rng.integers(0, 50, (120, 5)).astype(float)
        a1, c1, o1 = kl_kmeans(counts, 6, engine="chunked", chunk_size=7)
        a2, c2, o2 = kl_kmeans(counts, 6, engine="chunked", chunk_size=10_000)
        assert np.array_equal(a1, a2)
        assert np.array_equal(c1, c2)
        assert o1 == o2

    def test_cluster_models_engines_agree_on_quality(self, rng):
        counts = rng.integers(0, 60, (64, 8)).astype(float)
        r_dense = cluster_models(counts, 16.0, k_max=6, engine="dense")
        r_chunk = cluster_models(counts, 16.0, k_max=6, engine="chunked")
        # different Lloyd variants, same objective neighbourhood
        assert r_chunk.objective_bits <= r_dense.objective_bits * 1.05

    def test_unknown_engine_raises(self, rng):
        counts = rng.integers(0, 10, (5, 3)).astype(float)
        with pytest.raises(ValueError):
            kl_kmeans(counts, 2, engine="nope")


class TestSharedCodebook:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_build_and_roundtrip(self, task):
        fleet = small_fleet(task)
        shared = build_shared_codebook(list(fleet.values()))
        blob = shared.to_bytes()
        shared2 = SharedCodebook.from_bytes(blob)
        assert shared2.to_bytes() == blob
        assert shared2.task == task
        assert shared2.vars_comp.n_clusters >= 1
        if task == "regression":
            assert len(shared2.fleet_fit_values) >= 1
            assert np.array_equal(
                np.sort(shared2.fleet_fit_values), shared2.fleet_fit_values
            )

    def test_schema_mismatch_rejected(self):
        f1 = random_forest(seed=0, n_trees=3, d=5)
        f2 = random_forest(seed=1, n_trees=3, d=7)
        with pytest.raises(ValueError, match="schema"):
            build_shared_codebook([f1, f2])

    def test_cost_table_marks_uncodable(self):
        fleet = small_fleet()
        shared = build_shared_codebook(list(fleet.values()))
        cost = shared.vars_comp.cost_table()
        assert cost.shape[0] == shared.vars_comp.n_clusters
        assert np.isfinite(cost).any()
        for k, lengths in enumerate(shared.vars_comp.codebook_lengths):
            assert np.all(np.isinf(cost[k, np.asarray(lengths) == 0]))


class TestUserDelta:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_bit_exact_reconstruction_and_smaller_fleet(self, task):
        fleet = small_fleet(task, n_users=10)
        forests = list(fleet.values())
        shared = build_shared_codebook(forests)
        independent = sum(
            len(compress_forest(f).to_bytes()) for f in forests
        )
        store_total = len(shared.to_bytes())
        for f in forests:
            delta = encode_user_delta(f, shared)
            blob = delta.to_bytes()
            store_total += len(blob)
            rt = UserDelta.from_bytes(blob)
            assert rt.to_bytes() == blob
            rec = reconstruct_user(rt, shared)
            assert rec.equals(f)  # bit-exact, fit tables included
        assert store_total < independent

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_hydrated_predictions_match_inline_codec(self, rng, task):
        fleet = small_fleet(task, n_users=6)
        shared = build_shared_codebook(list(fleet.values()))
        x = rng.integers(0, 12, (80, 5))
        for f in fleet.values():
            comp = hydrate(encode_user_delta(f, shared), shared)
            inline = CompressedForest.from_bytes(
                compress_forest(f).to_bytes()
            )
            assert np.array_equal(
                predict_compressed(comp, x), predict_compressed(inline, x)
            )

    def test_late_onboarding_uses_local_clusters(self):
        # freeze a codebook on a 4-bin fleet, then onboard a user whose
        # forest uses bin symbols the fleet never produced: shared clusters
        # cannot code them, so the delta must carry user-local codebooks and
        # still reconstruct bit-exactly.
        d, n_bins = 3, 8
        meta = ForestMeta(
            n_features=d, task="classification", n_classes=2,
            n_bins_per_feature=np.full(d, n_bins, np.int32),
            n_train_obs=100,
        )

        def two_level_tree(thresh_sym):
            return Tree(
                np.array([0, -1, -1]),
                np.array([thresh_sym, -1, -1]),
                np.array([1, -1, -1]),
                np.array([2, -1, -1]),
                np.array([0, 0, 1], dtype=np.int64),
            )

        fleet = [
            Forest([two_level_tree(s % 4)] * 3, meta) for s in range(6)
        ]
        shared = build_shared_codebook(fleet)
        newcomer = Forest([two_level_tree(7)] * 3, meta)  # unseen symbol 7
        delta = encode_user_delta(newcomer, shared)
        assert sum(dc.n_local for dc in delta.splits_dc.values()) >= 1
        rt = UserDelta.from_bytes(delta.to_bytes())
        assert reconstruct_user(rt, shared).equals(newcomer)

    def test_regression_extra_fit_values_roundtrip(self):
        fleet = small_fleet("regression", n_users=5)
        shared = build_shared_codebook(list(fleet.values()))
        # newcomer with fit values outside the fleet table
        f = random_forest(
            seed=99, n_trees=4, d=5, max_depth=4, task="regression",
            n_bins=12, n_fit_values=11,
        )
        delta = encode_user_delta(f, shared)
        assert len(delta.extra_fit_values) == 11  # none in the fleet union
        rec = reconstruct_user(UserDelta.from_bytes(delta.to_bytes()), shared)
        assert rec.equals(f)


class TestForestStore:
    def test_store_roundtrip_and_registry(self):
        fleet = small_fleet(n_users=6)
        store = build_store(fleet)
        blob = store.to_bytes()
        store2 = ForestStore.from_bytes(blob)
        assert store2.to_bytes() == blob
        assert sorted(store2.user_ids) == sorted(fleet)
        for u, f in fleet.items():
            assert store2.reconstruct(u).equals(f)
            assert store2.n_trees(u) == f.n_trees

    def test_predict_matches_inline(self, rng):
        fleet = small_fleet(n_users=4)
        store = build_store(fleet)
        x = rng.integers(0, 12, (50, 5))
        for u, f in fleet.items():
            assert np.array_equal(
                store.predict(u, x),
                predict_compressed(compress_forest(f), x),
            )

    def test_tiles_cached_and_invalidated(self):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        u = store.user_ids[0]
        t1 = store.tiles(u, block_trees=4)
        misses = store.cache.misses
        t2 = store.tiles(u, block_trees=4)
        assert store.cache.misses == misses  # pure hits
        assert store.cache.hits >= len(t1)
        assert all(np.array_equal(a[0], b[0]) for a, b in zip(t1, t2))
        store.add_user(u, fleet[u])  # re-register -> caches invalidated
        assert all(k[0] != u for k in store.cache._tiles)

    def test_tile_cache_lru_eviction(self):
        cache = TileCache(capacity_trees=4)
        mk = lambda t: (np.zeros((t, 3)),) * 4
        cache.put(("a", 4, 0), mk(2))
        cache.put(("b", 4, 0), mk(2))
        assert cache.get(("a", 4, 0)) is not None  # refresh a
        cache.put(("c", 4, 0), mk(2))  # evicts b (LRU)
        assert cache.get(("b", 4, 0)) is None
        assert cache.get(("a", 4, 0)) is not None
        assert cache.evictions == 1


class TestSegmentedServing:
    def test_segmented_kernel_matches_reference(self, rng):
        import jax.numpy as jnp

        from repro.kernels.tree_predict.ref import (
            forest_predict_agg_segmented_reference,
        )
        from repro.kernels.tree_predict.tree_predict import (
            forest_predict_agg_segmented,
        )

        t, n, d, depth = 11, 90, 6, 5
        h = (1 << (depth + 1)) - 1
        feature = rng.integers(0, d, (t, h)).astype(np.int32)
        threshold = rng.integers(0, 16, (t, h)).astype(np.int32)
        inter = rng.random((t, h)) < 0.6
        inter[:, (h - 1) // 2 :] = False
        xb = rng.integers(0, 16, (n, d)).astype(np.int32)
        tseg = rng.integers(0, 4, t).astype(np.int32)
        oseg = rng.integers(0, 4, n).astype(np.int32)
        cases = [
            (0, rng.normal(size=(t, h)).astype(np.float32)),
            (3, rng.integers(0, 3, (t, h)).astype(np.float32)),
        ]
        for n_classes, fit in cases:
            got = forest_predict_agg_segmented(
                jnp.asarray(xb), oseg, tseg, jnp.asarray(feature),
                jnp.asarray(threshold), jnp.asarray(fit),
                jnp.asarray(inter), max_depth=depth, n_classes=n_classes,
                block_trees=4, block_obs=32,
            )
            ref = forest_predict_agg_segmented_reference(
                jnp.asarray(xb), jnp.asarray(oseg), jnp.asarray(tseg),
                jnp.asarray(feature), jnp.asarray(threshold),
                jnp.asarray(fit), jnp.asarray(inter), depth,
                n_classes=n_classes,
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5
            )

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_ragged_batch_matches_per_user_predict(self, rng, task):
        from repro.launch.serve_store import serve_store_batch

        fleet = small_fleet(task, n_users=5)
        store = build_store(fleet)
        users = store.user_ids
        requests = [
            (users[i % len(users)], rng.integers(0, 12, (30 + 7 * i, 5)))
            for i in range(7)
        ]
        preds = serve_store_batch(store, requests, block_trees=6)
        assert len(preds) == len(requests)
        for (u, x), p in zip(requests, preds):
            ref = store.predict(u, x)
            if task == "classification":
                assert np.array_equal(p, ref)  # integer votes: exact
            else:
                np.testing.assert_allclose(p, ref, rtol=1e-5, atol=1e-5)

    def test_empty_batch(self):
        fleet = small_fleet(n_users=2)
        store = build_store(fleet)
        from repro.launch.serve_store import serve_store_batch

        assert serve_store_batch(store, []) == []

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_zero_row_requests(self, rng, task):
        """Zero-row requests (mid-batch AND batch-final) must come back as
        empty predictions without disturbing their neighbours."""
        from repro.launch.serve_store import serve_store_batch

        fleet = small_fleet(task, n_users=3)
        store = build_store(fleet)
        u = store.user_ids
        x = rng.integers(0, 12, (20, 5)).astype(np.int32)
        empty = np.zeros((0, 5), np.int32)
        preds = serve_store_batch(
            store,
            [(u[0], x), (u[1], empty), (u[2], x), (u[0], empty)],
            block_trees=4,
        )
        assert preds[1].shape == (0,) and preds[3].shape == (0,)
        for idx, user in ((0, u[0]), (2, u[2])):
            ref = store.predict(user, x)
            if task == "classification":
                assert np.array_equal(preds[idx], ref)
            else:
                np.testing.assert_allclose(preds[idx], ref, rtol=1e-5,
                                           atol=1e-5)

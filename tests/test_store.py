"""Tier-1 tests for the multi-tenant forest store (ISSUE 2):

* fleet-scale Bregman clustering edge cases (K=1, K >= M, empty-cluster
  re-seeding, chunked-vs-dense assignment parity);
* shared codebook build + byte roundtrip;
* per-user delta encode -> decode bit-exactness (both tasks), hydration
  parity with the inline codec's predictions, and storage-size wins over
  independent per-forest compression;
* late onboarding against a frozen codebook (user-local clusters);
* the tile LRU cache (hits, eviction, invalidation);
* the segment-aware aggregation kernel vs its oracle;
* ragged multi-tenant serving vs per-user predict_compressed.

No importorskip: everything here runs on the baked-in numpy + jax stack.
"""
import numpy as np
import pytest

from repro.core import CompressedForest, compress_forest, predict_compressed
from repro.core.bregman import cluster_models, kl_assign, kl_kmeans
from repro.core.tree import Forest, ForestMeta, Tree
from repro.store import (
    ForestStore,
    SharedCodebook,
    TileCache,
    UserDelta,
    build_shared_codebook,
    build_store,
    encode_user_delta,
    hydrate,
    make_synthetic_fleet,
    reconstruct_user,
)

from conftest import random_forest


def small_fleet(task="classification", n_users=8, seed=0):
    return make_synthetic_fleet(
        n_users, task=task, n_trees=(5, 9), d=5, n_bins=12, max_depth=5,
        seed=seed,
    )


class TestBregmanEdgeCases:
    def test_k_equals_one(self, rng):
        counts = rng.integers(0, 40, (30, 6)).astype(float)
        for engine in ("dense", "chunked"):
            assign, cent, obj = kl_kmeans(counts, 1, engine=engine)
            assert np.all(assign == 0)
            assert cent.shape == (1, 6)
            assert obj >= 0

    def test_k_at_least_m(self, rng):
        counts = rng.integers(1, 40, (4, 6)).astype(float)
        for engine in ("dense", "chunked"):
            assign, cent, obj = kl_kmeans(counts, 10, engine=engine)
            assert cent.shape[0] == 4  # k clamped to M
            # every model gets (numerically) its own centroid: loss ~ 0
            # (the dense engine accumulates in float32 under jit)
            assert obj < 1e-3

    def test_empty_cluster_reseeding(self):
        # 3 well-separated groups but MANY duplicate rows: naive Lloyd with
        # k=4 empties a cluster; the chunked engine must re-seed it
        # deterministically and still converge to <= 3 used clusters that
        # cover the data.
        a = np.tile([100, 1, 1], (10, 1))
        b = np.tile([1, 100, 1], (10, 1))
        c = np.tile([1, 1, 100], (10, 1))
        counts = np.concatenate([a, b, c]).astype(float)
        assign1, cent1, obj1 = kl_kmeans(counts, 4, engine="chunked", seed=0)
        assign2, cent2, obj2 = kl_kmeans(counts, 4, engine="chunked", seed=0)
        assert np.array_equal(assign1, assign2)  # deterministic
        assert obj1 == obj2
        # the three groups must land in three distinct clusters
        groups = [np.unique(assign1[i * 10 : (i + 1) * 10]) for i in range(3)]
        assert all(len(g) == 1 for g in groups)
        assert len({int(g[0]) for g in groups}) == 3

    def test_chunked_vs_dense_assignment_parity(self, rng):
        counts = rng.integers(0, 100, (257, 9)).astype(float)
        centroids = rng.dirichlet(np.ones(9), size=7)
        a_dense, d_dense = kl_assign(counts, centroids, chunk_size=None)
        for chunk in (1, 13, 64, 10_000):
            a_chunk, d_chunk = kl_assign(counts, centroids, chunk_size=chunk)
            assert np.array_equal(a_dense, a_chunk)
            # BLAS reduction order varies with chunk shape: ~ulp agreement
            np.testing.assert_allclose(d_dense, d_chunk, rtol=1e-12)

    def test_chunked_kmeans_chunk_size_invariant(self, rng):
        counts = rng.integers(0, 50, (120, 5)).astype(float)
        a1, c1, o1 = kl_kmeans(counts, 6, engine="chunked", chunk_size=7)
        a2, c2, o2 = kl_kmeans(counts, 6, engine="chunked", chunk_size=10_000)
        assert np.array_equal(a1, a2)
        assert np.array_equal(c1, c2)
        assert o1 == o2

    def test_cluster_models_engines_agree_on_quality(self, rng):
        counts = rng.integers(0, 60, (64, 8)).astype(float)
        r_dense = cluster_models(counts, 16.0, k_max=6, engine="dense")
        r_chunk = cluster_models(counts, 16.0, k_max=6, engine="chunked")
        # different Lloyd variants, same objective neighbourhood
        assert r_chunk.objective_bits <= r_dense.objective_bits * 1.05

    def test_unknown_engine_raises(self, rng):
        counts = rng.integers(0, 10, (5, 3)).astype(float)
        with pytest.raises(ValueError):
            kl_kmeans(counts, 2, engine="nope")


class TestSharedCodebook:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_build_and_roundtrip(self, task):
        fleet = small_fleet(task)
        shared = build_shared_codebook(list(fleet.values()))
        blob = shared.to_bytes()
        shared2 = SharedCodebook.from_bytes(blob)
        assert shared2.to_bytes() == blob
        assert shared2.task == task
        assert shared2.vars_comp.n_clusters >= 1
        if task == "regression":
            assert len(shared2.fleet_fit_values) >= 1
            assert np.array_equal(
                np.sort(shared2.fleet_fit_values), shared2.fleet_fit_values
            )

    def test_schema_mismatch_rejected(self):
        f1 = random_forest(seed=0, n_trees=3, d=5)
        f2 = random_forest(seed=1, n_trees=3, d=7)
        with pytest.raises(ValueError, match="schema"):
            build_shared_codebook([f1, f2])

    def test_cost_table_marks_uncodable(self):
        fleet = small_fleet()
        shared = build_shared_codebook(list(fleet.values()))
        cost = shared.vars_comp.cost_table()
        assert cost.shape[0] == shared.vars_comp.n_clusters
        assert np.isfinite(cost).any()
        for k, lengths in enumerate(shared.vars_comp.codebook_lengths):
            assert np.all(np.isinf(cost[k, np.asarray(lengths) == 0]))


class TestUserDelta:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_bit_exact_reconstruction_and_smaller_fleet(self, task):
        fleet = small_fleet(task, n_users=10)
        forests = list(fleet.values())
        shared = build_shared_codebook(forests)
        independent = sum(
            len(compress_forest(f).to_bytes()) for f in forests
        )
        store_total = len(shared.to_bytes())
        for f in forests:
            delta = encode_user_delta(f, shared)
            blob = delta.to_bytes()
            store_total += len(blob)
            rt = UserDelta.from_bytes(blob)
            assert rt.to_bytes() == blob
            rec = reconstruct_user(rt, shared)
            assert rec.equals(f)  # bit-exact, fit tables included
        assert store_total < independent

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_hydrated_predictions_match_inline_codec(self, rng, task):
        fleet = small_fleet(task, n_users=6)
        shared = build_shared_codebook(list(fleet.values()))
        x = rng.integers(0, 12, (80, 5))
        for f in fleet.values():
            comp = hydrate(encode_user_delta(f, shared), shared)
            inline = CompressedForest.from_bytes(
                compress_forest(f).to_bytes()
            )
            assert np.array_equal(
                predict_compressed(comp, x), predict_compressed(inline, x)
            )

    def test_late_onboarding_uses_local_clusters(self):
        # freeze a codebook on a 4-bin fleet, then onboard a user whose
        # forest uses bin symbols the fleet never produced: shared clusters
        # cannot code them, so the delta must carry user-local codebooks and
        # still reconstruct bit-exactly.
        d, n_bins = 3, 8
        meta = ForestMeta(
            n_features=d, task="classification", n_classes=2,
            n_bins_per_feature=np.full(d, n_bins, np.int32),
            n_train_obs=100,
        )

        def two_level_tree(thresh_sym):
            return Tree(
                np.array([0, -1, -1]),
                np.array([thresh_sym, -1, -1]),
                np.array([1, -1, -1]),
                np.array([2, -1, -1]),
                np.array([0, 0, 1], dtype=np.int64),
            )

        fleet = [
            Forest([two_level_tree(s % 4)] * 3, meta) for s in range(6)
        ]
        shared = build_shared_codebook(fleet)
        newcomer = Forest([two_level_tree(7)] * 3, meta)  # unseen symbol 7
        delta = encode_user_delta(newcomer, shared)
        assert sum(dc.n_local for dc in delta.splits_dc.values()) >= 1
        rt = UserDelta.from_bytes(delta.to_bytes())
        assert reconstruct_user(rt, shared).equals(newcomer)

    def test_regression_extra_fit_values_roundtrip(self):
        fleet = small_fleet("regression", n_users=5)
        shared = build_shared_codebook(list(fleet.values()))
        # newcomer with fit values outside the fleet table
        f = random_forest(
            seed=99, n_trees=4, d=5, max_depth=4, task="regression",
            n_bins=12, n_fit_values=11,
        )
        delta = encode_user_delta(f, shared)
        assert len(delta.extra_fit_values) == 11  # none in the fleet union
        rec = reconstruct_user(UserDelta.from_bytes(delta.to_bytes()), shared)
        assert rec.equals(f)


class TestForestStore:
    def test_store_roundtrip_and_registry(self):
        fleet = small_fleet(n_users=6)
        store = build_store(fleet)
        blob = store.to_bytes()
        store2 = ForestStore.from_bytes(blob)
        assert store2.to_bytes() == blob
        assert sorted(store2.user_ids) == sorted(fleet)
        for u, f in fleet.items():
            assert store2.reconstruct(u).equals(f)
            assert store2.n_trees(u) == f.n_trees

    def test_predict_matches_inline(self, rng):
        fleet = small_fleet(n_users=4)
        store = build_store(fleet)
        x = rng.integers(0, 12, (50, 5))
        for u, f in fleet.items():
            assert np.array_equal(
                store.predict(u, x),
                predict_compressed(compress_forest(f), x),
            )

    def test_tiles_cached_and_invalidated(self):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        u = store.user_ids[0]
        t1 = store.tiles(u, block_trees=4)
        misses = store.cache.misses
        t2 = store.tiles(u, block_trees=4)
        assert store.cache.misses == misses  # pure hits
        assert store.cache.hits >= len(t1)
        assert all(np.array_equal(a[0], b[0]) for a, b in zip(t1, t2))
        store.add_user(u, fleet[u])  # re-register -> caches invalidated
        assert all(k[0] != u for k in store.cache._tiles)

    def test_tile_cache_lru_eviction(self):
        cache = TileCache(capacity_trees=4)
        mk = lambda t: (np.zeros((t, 3)),) * 4
        cache.put(("a", 4, 0), mk(2))
        cache.put(("b", 4, 0), mk(2))
        assert cache.get(("a", 4, 0)) is not None  # refresh a
        cache.put(("c", 4, 0), mk(2))  # evicts b (LRU)
        assert cache.get(("b", 4, 0)) is None
        assert cache.get(("a", 4, 0)) is not None
        assert cache.evictions == 1


class TestSegmentedServing:
    def test_segmented_kernel_matches_reference(self, rng):
        import jax.numpy as jnp

        from repro.kernels.tree_predict.ref import (
            forest_predict_agg_segmented_reference,
        )
        from repro.kernels.tree_predict.tree_predict import (
            forest_predict_agg_segmented,
        )

        t, n, d, depth = 11, 90, 6, 5
        h = (1 << (depth + 1)) - 1
        feature = rng.integers(0, d, (t, h)).astype(np.int32)
        threshold = rng.integers(0, 16, (t, h)).astype(np.int32)
        inter = rng.random((t, h)) < 0.6
        inter[:, (h - 1) // 2 :] = False
        xb = rng.integers(0, 16, (n, d)).astype(np.int32)
        tseg = rng.integers(0, 4, t).astype(np.int32)
        oseg = rng.integers(0, 4, n).astype(np.int32)
        cases = [
            (0, rng.normal(size=(t, h)).astype(np.float32)),
            (3, rng.integers(0, 3, (t, h)).astype(np.float32)),
        ]
        for n_classes, fit in cases:
            got = forest_predict_agg_segmented(
                jnp.asarray(xb), oseg, tseg, jnp.asarray(feature),
                jnp.asarray(threshold), jnp.asarray(fit),
                jnp.asarray(inter), max_depth=depth, n_classes=n_classes,
                block_trees=4, block_obs=32,
            )
            ref = forest_predict_agg_segmented_reference(
                jnp.asarray(xb), jnp.asarray(oseg), jnp.asarray(tseg),
                jnp.asarray(feature), jnp.asarray(threshold),
                jnp.asarray(fit), jnp.asarray(inter), depth,
                n_classes=n_classes,
            )
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5
            )

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_ragged_batch_matches_per_user_predict(self, rng, task):
        from repro.serving import ForestServer

        fleet = small_fleet(task, n_users=5)
        store = build_store(fleet)
        users = store.user_ids
        requests = [
            (users[i % len(users)], rng.integers(0, 12, (30 + 7 * i, 5)))
            for i in range(7)
        ]
        preds = ForestServer(store).serve(requests, block_trees=6)
        assert len(preds) == len(requests)
        for (u, x), p in zip(requests, preds):
            ref = store.predict(u, x)
            if task == "classification":
                assert np.array_equal(p, ref)  # integer votes: exact
            else:
                np.testing.assert_allclose(p, ref, rtol=1e-5, atol=1e-5)

    def test_empty_batch(self):
        fleet = small_fleet(n_users=2)
        store = build_store(fleet)
        from repro.serving import ForestServer

        assert ForestServer(store).serve([]) == []

    @pytest.mark.parametrize("task", ["classification", "regression"])
    def test_zero_row_requests(self, rng, task):
        """Zero-row requests (mid-batch AND batch-final) must come back as
        empty predictions without disturbing their neighbours."""
        from repro.serving import ForestServer

        fleet = small_fleet(task, n_users=3)
        store = build_store(fleet)
        u = store.user_ids
        x = rng.integers(0, 12, (20, 5)).astype(np.int32)
        empty = np.zeros((0, 5), np.int32)
        preds = ForestServer(store).serve(
            [(u[0], x), (u[1], empty), (u[2], x), (u[0], empty)],
            block_trees=4,
        )
        assert preds[1].shape == (0,) and preds[3].shape == (0,)
        for idx, user in ((0, u[0]), (2, u[2])):
            ref = store.predict(user, x)
            if task == "classification":
                assert np.array_equal(preds[idx], ref)
            else:
                np.testing.assert_allclose(preds[idx], ref, rtol=1e-5,
                                           atol=1e-5)


class TestCostWeightedEviction:
    def test_equal_costs_reduce_to_lru(self):
        cache = TileCache(capacity_trees=4)
        mk = lambda t: (np.zeros((t, 3)),) * 4
        cache.put(("a", 4, 0), mk(2))
        cache.put(("b", 4, 0), mk(2))
        assert cache.get(("a", 4, 0)) is not None
        cache.put(("c", 4, 0), mk(2))  # evicts b: same cost, older access
        assert cache.get(("b", 4, 0)) is None
        assert cache.get(("a", 4, 0)) is not None
        assert cache.evictions == 1

    def test_expensive_tile_outlives_older_cheap_tile(self):
        # deep (h=15 => cost 4*8=32) vs shallow (h=3 => cost 4*2=8): at
        # equal recency the cheap-to-re-decode tile is evicted first even
        # though the expensive one is OLDER
        cache = TileCache(capacity_trees=10)
        deep = (np.zeros((4, 15)),) * 4
        shallow = (np.zeros((4, 3)),) * 4
        cache.put(("deep", 4, 0), deep)
        cache.put(("shallow", 4, 0), shallow)
        cache.put(("x", 4, 0), (np.zeros((4, 3)),) * 4)
        assert ("deep", 4, 0) in cache
        assert ("shallow", 4, 0) not in cache

    def test_clock_ages_out_idle_expensive_tiles(self):
        # GreedyDual clock: repeated insert/evict churn of cheap tiles
        # raises the clock past an idle expensive tile's priority
        cache = TileCache(capacity_trees=8)
        cache.put(("deep", 4, 0), (np.zeros((4, 15)),) * 4)  # prio 32
        for i in range(20):  # churn: cheap tiles, each re-accessed
            cache.put(("u%d" % i, 4, 0), (np.zeros((4, 3)),) * 4)
        assert ("deep", 4, 0) not in cache  # eventually evicted

    def test_per_user_hit_rates(self):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        u0, u1 = store.user_ids[:2]
        store.tiles(u0, block_trees=4)  # decode misses
        store.tiles(u0, block_trees=4)  # pure hits
        store.tiles(u1, block_trees=4)  # decode misses only
        per_user = store.cache.stats()["per_user"]
        assert per_user[u0]["hits"] > 0 and per_user[u0]["misses"] > 0
        assert 0.0 < per_user[u0]["hit_rate"] < 1.0
        assert per_user[u1]["hits"] == 0 and per_user[u1]["misses"] > 0
        assert per_user[u1]["hit_rate"] == 0.0


class TestTileArena:
    def _pack_host(self, store, users, block_trees=4):
        """Host-side oracle: what the arena gather must reproduce."""
        from repro.kernels.tree_predict.tree_predict import fuse_node_attrs

        arena = store.arena
        h = arena.h
        feats, fits = [], []
        for u in users:
            for f, t, ft, it in store.tiles(u, block_trees):
                code = fuse_node_attrs(f, t, it, arena.tb)
                pad = ((0, 0), (0, h - code.shape[1]))
                feats.append(np.pad(code, pad))
                fits.append(np.pad(ft.astype(np.float32), pad))
        return np.concatenate(feats), np.concatenate(fits)

    def test_arena_pack_matches_packed_reference(self, rng):
        """The arena's fused device tiles drive the packed reference oracle
        to the same votes as per-user predict_compressed."""
        import jax.numpy as jnp

        from repro.kernels.tree_predict.ref import (
            forest_predict_agg_segmented_packed_reference,
        )

        fleet = small_fleet(n_users=4)
        store = build_store(fleet)
        users = store.user_ids
        code, fit, tseg, counts, md = store.arena_pack(users, block_trees=4)
        x = rng.integers(0, 12, (25, 5)).astype(np.int32)
        for s, u in enumerate(users):
            votes = forest_predict_agg_segmented_packed_reference(
                jnp.asarray(x), jnp.full(len(x), s, np.int32),
                jnp.asarray(code), jnp.asarray(fit), jnp.asarray(tseg),
                md, store.arena.tb2, n_classes=2,
            )
            assert np.array_equal(
                np.asarray(votes).argmax(-1).astype(np.float64),
                store.predict(u, x),
            )

    def test_gather_matches_host_pack(self):
        fleet = small_fleet(n_users=5)
        store = build_store(fleet)
        users = store.user_ids[:4]
        code, fit, tseg, counts, md = store.arena_pack(users, block_trees=4)
        code_h, fit_h = self._pack_host(store, users)
        t = code_h.shape[0]
        assert np.array_equal(np.asarray(code)[:t], code_h)
        assert np.array_equal(np.asarray(fit)[:t], fit_h)
        assert np.array_equal(
            tseg[:t], np.repeat(np.arange(len(users)), counts)
        )
        assert np.all(tseg[t:] == -1)  # padding rows never match a row
        assert len(tseg) % 4 == 0

    def test_gather_is_warm_after_admission(self):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        users = store.user_ids
        store.arena_pack(users, block_trees=4)
        adm = store.arena.admissions
        store.arena_pack(users, block_trees=4)  # warm: pure index-gather
        assert store.arena.admissions == adm

    def test_width_grows_for_deeper_user(self):
        shallow = small_fleet(n_users=3)  # max_depth 5
        store = build_store(shallow)
        u = store.user_ids
        store.arena_pack([u[0]], block_trees=4)
        h0 = store.arena.h
        deep = random_forest(seed=7, n_trees=4, d=5, max_depth=7, n_bins=12)
        store.add_user("deep", deep)
        code, fit, tseg, counts, md = store.arena_pack(
            [u[0], "deep"], block_trees=4
        )
        assert store.arena.h > h0
        assert md == 7
        assert np.asarray(code).shape[1] == store.arena.h

    def test_eviction_and_compaction(self):
        fleet = small_fleet(n_users=6)
        store = build_store(fleet, arena_capacity_trees=16)
        users = store.user_ids
        for u in users:
            store.arena_pack([u], block_trees=4)
        arena = store.arena
        assert arena.resident_trees <= 16 or len(arena._runs) == 1
        assert arena.evictions > 0
        # surviving runs still gather correctly after compaction
        resident = [u for u in users if u in arena]
        code, fit, tseg, counts, _ = store.arena_pack(
            resident, block_trees=4
        )
        code_h, _ = self._pack_host(store, resident)
        assert np.array_equal(
            np.asarray(code)[: code_h.shape[0]], code_h
        )

    def test_invalidated_on_reregister(self):
        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        u = store.user_ids[0]
        store.arena_pack([u], block_trees=4)
        assert u in store.arena
        store.add_user(u, fleet[u])
        assert u not in store.arena


class TestServingEngines:
    @pytest.mark.parametrize("task", ["classification", "regression"])
    @pytest.mark.parametrize("engine", ["pipelined", "sharded"])
    def test_engines_match_simple_and_reference(self, rng, task, engine):
        from repro.serving import ForestServer

        fleet = small_fleet(task, n_users=5)
        store = build_store(fleet)
        users = store.user_ids
        requests = [
            (users[i % len(users)], rng.integers(0, 12, (30 + 7 * i, 5)))
            for i in range(7)
        ]
        got = ForestServer(store).serve(requests, engine=engine)
        ref = ForestServer(store).serve(requests, engine="simple")
        for (u, x), p, q in zip(requests, got, ref):
            exact = store.predict(u, x)
            if task == "classification":
                assert np.array_equal(p, q)  # integer votes: bit-exact
                assert np.array_equal(p, exact)
            else:
                np.testing.assert_allclose(p, q, rtol=1e-5, atol=1e-5)
                np.testing.assert_allclose(p, exact, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("engine", ["pipelined", "sharded"])
    def test_zero_row_requests_new_engines(self, rng, engine):
        from repro.serving import ForestServer

        fleet = small_fleet(n_users=3)
        store = build_store(fleet)
        u = store.user_ids
        x = rng.integers(0, 12, (20, 5)).astype(np.int32)
        empty = np.zeros((0, 5), np.int32)
        preds = ForestServer(store).serve(
            [(u[0], x), (u[1], empty), (u[2], x), (u[0], empty)],
            engine=engine,
        )
        assert preds[1].shape == (0,) and preds[3].shape == (0,)
        for idx, user in ((0, u[0]), (2, u[2])):
            assert np.array_equal(preds[idx], store.predict(user, x))

    def test_unknown_engine_raises(self):
        from repro.serving import ForestServer

        fleet = small_fleet(n_users=2)
        store = build_store(fleet)
        with pytest.raises(ValueError, match="engine"):
            ForestServer(store).serve(
                [(store.user_ids[0], np.zeros((1, 5), np.int32))],
                engine="nope",
            )

    def test_pipelined_kernel_unsorted_segments(self, rng):
        """Conservative chunk ranges keep the pipelined kernel correct on
        UNSORTED segment maps (the serving driver sorts; the kernel must
        not rely on it)."""
        import jax.numpy as jnp

        from repro.kernels.tree_predict.ref import (
            forest_predict_agg_segmented_reference,
        )
        from repro.kernels.tree_predict.tree_predict import (
            forest_predict_agg_segmented,
        )

        t, n, d, depth = 13, 70, 5, 4
        h = (1 << (depth + 1)) - 1
        feature = rng.integers(0, d, (t, h)).astype(np.int32)
        threshold = rng.integers(0, 16, (t, h)).astype(np.int32)
        inter = rng.random((t, h)) < 0.6
        inter[:, (h - 1) // 2 :] = False
        xb = rng.integers(0, 16, (n, d)).astype(np.int32)
        tseg = rng.integers(0, 6, t).astype(np.int32)  # unsorted
        oseg = rng.integers(0, 6, n).astype(np.int32)  # unsorted
        fit = rng.integers(0, 3, (t, h)).astype(np.float32)
        got = forest_predict_agg_segmented(
            xb, oseg, tseg, feature, threshold, fit, inter,
            max_depth=depth, n_classes=3, block_trees=4, block_obs=32,
            engine="pipelined",
        )
        ref = forest_predict_agg_segmented_reference(
            jnp.asarray(xb), jnp.asarray(oseg), jnp.asarray(tseg),
            jnp.asarray(feature), jnp.asarray(threshold), jnp.asarray(fit),
            jnp.asarray(inter), depth, n_classes=3,
        )
        assert np.array_equal(np.asarray(got), np.asarray(ref))

    def test_pipelined_rejects_tracers(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels.tree_predict.tree_predict import (
            forest_predict_agg_segmented,
        )

        t, n, d, depth = 4, 8, 3, 2
        h = (1 << (depth + 1)) - 1
        args = (
            jnp.zeros((n, d), jnp.int32), jnp.zeros(n, jnp.int32),
            jnp.zeros(t, jnp.int32), jnp.zeros((t, h), jnp.int32),
            jnp.zeros((t, h), jnp.int32), jnp.zeros((t, h), jnp.float32),
            jnp.zeros((t, h), bool),
        )

        def f(*a):
            return forest_predict_agg_segmented(
                *a, max_depth=depth, engine="pipelined"
            )

        with pytest.raises(ValueError, match="pipelined"):
            jax.jit(f)(*args)
        # engine=None silently falls back to the simple oracle under jit
        out = jax.jit(
            lambda *a: forest_predict_agg_segmented(*a, max_depth=depth)
        )(*args)
        assert out.shape == (n,)


class TestMixedDepthSharding:
    def test_piecewise_gathers_share_width_after_ensure(self, rng):
        """The sharded engine gathers per shard; arena_ensure of the WHOLE
        batch must come first so a later shard's deeper user cannot grow
        the arena width after an earlier shard was gathered (regression:
        mixed-depth fleets crashed jnp.stack on multi-device hosts)."""
        shallow = random_forest(seed=0, n_trees=3, d=5, max_depth=2,
                                n_bins=12)
        deep = random_forest(seed=1, n_trees=3, d=5, max_depth=6,
                             n_bins=12)
        shared = build_shared_codebook([shallow, deep])
        store = ForestStore(shared)
        store.add_user("shallow", shallow)
        store.add_user("deep", deep)
        store.arena_ensure(["shallow", "deep"], block_trees=4)
        code_a, *_ = store.arena_pack(["shallow"], block_trees=4)
        code_b, *_ = store.arena_pack(["deep"], block_trees=4)
        assert code_a.shape[1] == code_b.shape[1] == store.arena.h

        from repro.serving import ForestServer

        x = rng.integers(0, 12, (15, 5)).astype(np.int32)
        reqs = [("shallow", x), ("deep", x)]
        for engine in ("pipelined", "sharded"):
            preds = ForestServer(store).serve(reqs, engine=engine)
            for (u, xi), p in zip(reqs, preds):
                assert np.array_equal(p, store.predict(u, xi)), engine


class TestArenaWidthShrink:
    def test_width_and_depth_shrink_after_deep_user_leaves(self, rng):
        """Evicting/invalidating the one deep user must shrink the arena's
        common width and traversal depth back to the survivors' maximum —
        not inflate every later batch forever."""
        shallow = {
            f"s{i}": random_forest(seed=i, n_trees=3, d=5, max_depth=3,
                                   n_bins=12)
            for i in range(3)
        }
        deep = random_forest(seed=11, n_trees=3, d=5, max_depth=7,
                             n_bins=12)  # realized depth 7 at this seed
        shared = build_shared_codebook(list(shallow.values()) + [deep])
        store = ForestStore(shared)
        for u, f in shallow.items():
            store.add_user(u, f)
        store.add_user("deep", deep)
        store.arena_pack(list(shallow) + ["deep"], block_trees=4)
        h_wide = store.arena.h
        assert store.arena.max_depth == 7
        store.arena.invalidate("deep")
        assert store.arena.h < h_wide
        assert store.arena.max_depth == 3
        # surviving users still serve correctly at the shrunk width
        from repro.serving import ForestServer

        x = rng.integers(0, 12, (12, 5)).astype(np.int32)
        reqs = [(u, x) for u in shallow]
        for (u, xi), p in zip(reqs, ForestServer(store).serve(
            reqs, engine="pipelined"
        )):
            assert np.array_equal(p, store.predict(u, xi))

"""Continuous-batching scheduler + self-driving lifecycle (ISSUE 7).

Everything here runs under the VirtualClock: batching decisions,
deadline accounting, lifecycle polling, and migration pacing are pure
functions of (submissions, clock advances), so every assertion is
deterministic and bit-exact.
"""
import numpy as np
import pytest

from repro.core.compressed_predict import predict_compressed
from repro.runtime.chaos import BatchFaults, poison_user
from repro.sched import (
    AdmissionError,
    LifecycleDriver,
    MicroBatcher,
    PipelinedExecutor,
    RequestQueue,
    Scheduler,
    VirtualClock,
    WallClock,
)
from repro.serving.server import ForestServer
from repro.store.fleet import make_drifted_fleet, make_synthetic_fleet
from repro.store.lifecycle import drift_report
from repro.store.runtime import build_store


def fleet_server(n_users=6, task="classification", seed=0):
    forests = make_synthetic_fleet(
        n_users, task, n_trees=(4, 8), max_depth=4, seed=seed
    )
    store = build_store(forests)
    return ForestServer(store), store, sorted(forests)


def drifted_server(n_users=10, late_fraction=0.3, seed=0):
    initial, late = make_drifted_fleet(
        n_users, late_fraction=late_fraction, task="classification",
        n_trees=(4, 8), max_depth=4, seed=seed,
    )
    store = build_store(initial)
    for u, f in late.items():
        store.add_user(u, f)
    return ForestServer(store), store, sorted({**initial, **late})


def make_rows(rng, store, n):
    return rng.integers(
        0, 64, size=(n, store.shared.n_features), dtype=np.int32
    )


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

class TestClocks:
    def test_virtual_clock_advances(self):
        c = VirtualClock(start=10.0)
        assert c.now() == 10.0
        c.advance(2.5)
        c.sleep(0.5)
        assert c.now() == 13.0

    def test_virtual_clock_rejects_negative(self):
        with pytest.raises(ValueError):
            VirtualClock().advance(-1.0)

    def test_wall_clock_monotonic(self):
        c = WallClock()
        assert c.now() <= c.now()


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------

class TestRequestQueue:
    def test_fifo_and_deadlines(self):
        q = RequestQueue(slo_s=0.25)
        r1 = q.submit("a", np.zeros((4, 3), np.int32), now=1.0)
        r2 = q.submit("a", np.zeros((2, 3), np.int32), now=2.0)
        r3 = q.submit("b", np.zeros((8, 3), np.int32), now=1.5)
        assert (r1.deadline, r2.deadline, r3.deadline) == (1.25, 2.25, 1.75)
        assert q.n_pending == 3 and q.pending_rows == 14
        # head deadlines per tenant; earliest servable across tenants
        assert q.head_deadlines() == {"a": 1.25, "b": 1.75}
        assert q.oldest_head_deadline() == 1.25
        assert q.pop("a") is r1
        assert q.oldest_head_deadline() == 1.75

    def test_admission_bounds(self):
        q = RequestQueue(
            max_pending_requests=2, max_pending_rows=100,
            max_pending_per_tenant=1,
        )
        q.submit("a", np.zeros((4, 3), np.int32), now=0.0)
        with pytest.raises(AdmissionError):  # per-tenant bound
            q.submit("a", np.zeros((4, 3), np.int32), now=0.0)
        q.submit("b", np.zeros((4, 3), np.int32), now=0.0)
        with pytest.raises(AdmissionError):  # global request bound
            q.submit("c", np.zeros((4, 3), np.int32), now=0.0)
        q.pop("a")
        with pytest.raises(AdmissionError):  # global row bound
            q.submit("c", np.zeros((99, 3), np.int32), now=0.0)
        assert q.stats()["n_rejected"] == 3

    def test_rejects_non_2d_rows(self):
        with pytest.raises(ValueError):
            RequestQueue().submit("a", np.zeros(4, np.int32), now=0.0)


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------

class TestMicroBatcher:
    def test_rows_trigger(self):
        q = RequestQueue(slo_s=10.0)
        b = MicroBatcher(max_rows=16)
        q.submit("a", np.zeros((8, 3), np.int32), now=0.0)
        assert b.due(q, 0.0) is None
        q.submit("b", np.zeros((8, 3), np.int32), now=0.0)
        assert b.due(q, 0.0) == "rows"
        batch = b.form(q, 0.0)
        assert batch.trigger == "rows" and batch.n_rows == 16
        assert q.n_pending == 0

    def test_deadline_trigger_fires_at_headroom(self):
        q = RequestQueue(slo_s=1.0)
        b = MicroBatcher(max_rows=1 << 20, plan_headroom_s=0.1)
        q.submit("a", np.zeros((4, 3), np.int32), now=0.0)  # deadline 1.0
        assert b.due(q, 0.85) is None
        assert b.due(q, 0.9) == "deadline"
        batch = b.form(q, 0.9)
        assert batch.trigger == "deadline" and batch.n_requests == 1

    def test_tenant_coherent_urgency_order_canonical_sort(self):
        q = RequestQueue(slo_s=1.0)
        b = MicroBatcher(max_rows=16)
        # b is more urgent (earlier deadline) than a, but batch order is
        # canonical (user_id, seq); a's two requests ride in one batch
        ra2 = q.submit("a", np.zeros((4, 3), np.int32), now=0.5)
        ra1 = q.submit("a", np.zeros((4, 3), np.int32), now=0.6)
        rb = q.submit("b", np.zeros((8, 3), np.int32), now=0.0)
        batch = b.form(q, 2.0)
        assert batch.requests == [ra2, ra1, rb]
        assert batch.users == ["a", "b"]

    def test_budget_leaves_tail_queued(self):
        q = RequestQueue(slo_s=1.0)
        b = MicroBatcher(max_rows=8)
        q.submit("a", np.zeros((8, 3), np.int32), now=0.0)
        q.submit("a", np.zeros((8, 3), np.int32), now=0.0)
        batch = b.form(q, 10.0)
        assert batch.n_requests == 1 and q.n_pending == 1

    def test_oversized_first_request_not_starved(self):
        q = RequestQueue(slo_s=1.0)
        b = MicroBatcher(max_rows=8)
        q.submit("a", np.zeros((32, 3), np.int32), now=0.0)
        batch = b.form(q, 10.0)
        assert batch.n_rows == 32

    def test_no_trigger_no_batch(self):
        q = RequestQueue(slo_s=10.0)
        b = MicroBatcher(max_rows=1 << 20)
        q.submit("a", np.zeros((4, 3), np.int32), now=0.0)
        assert b.form(q, 0.0) is None
        assert b.form(q, 0.0, flush=True) is not None


# ---------------------------------------------------------------------------
# scheduler end-to-end (virtual clock, inline executor)
# ---------------------------------------------------------------------------

class TestSchedulerEndToEnd:
    def test_bit_exact_vs_predict_compressed_and_slo(self):
        server, store, users = fleet_server()
        clock = VirtualClock()
        sched = Scheduler(
            server, clock=clock, queue=RequestQueue(slo_s=0.5),
            batcher=MicroBatcher(max_rows=64),
        )
        rng = np.random.default_rng(1)
        tickets = []
        for i in range(40):
            u = users[int(rng.integers(len(users)))]
            rows = make_rows(rng, store, int(rng.integers(4, 24)))
            tickets.append((u, rows, sched.submit(u, rows)))
            clock.advance(0.02)
            sched.pump()
        sched.close()
        for u, rows, t in tickets:
            assert t.done and t.status == "ok"
            ref = predict_compressed(store.hydrate(u), rows)
            assert np.array_equal(t.prediction, ref)
        lat = sched.latency_stats()
        assert lat["n_completed"] == 40
        assert lat["deadline_misses"] == 0  # virtual clock: batching
        # delay is bounded by the deadline trigger by construction
        assert set(sched.batcher.stats()["trigger_counts"]) <= {
            "rows", "deadline", "flush"
        }

    def test_overlap_matches_inline(self):
        # same seeded trace through the threaded and the inline
        # executor -> identical predictions
        results = []
        for overlap in (False, True):
            server, store, users = fleet_server(seed=5)
            sched = Scheduler(
                server, clock=VirtualClock() if not overlap else WallClock(),
                batcher=MicroBatcher(max_rows=32), overlap=overlap,
            )
            rng = np.random.default_rng(7)  # re-seeded: same trace twice
            tickets = []
            for _ in range(12):
                u = users[int(rng.integers(len(users)))]
                tickets.append(sched.submit(u, make_rows(rng, store, 8)))
                sched.pump()
            sched.close()
            assert sched.executor.overlap is overlap
            results.append([t.prediction for t in tickets])
        for a, b in zip(*results):
            assert np.array_equal(a, b)

    def test_plan_cache_hits_on_recurring_trace(self):
        server, store, users = fleet_server()
        clock = VirtualClock()
        sched = Scheduler(
            server, clock=clock, batcher=MicroBatcher(max_rows=64),
        )
        rng = np.random.default_rng(3)
        # run the identical batch signature twice: deterministic batching
        # means the second pass hits the cross-batch PlanCache
        for _ in range(2):
            for u in users[:4]:
                sched.submit(u, make_rows(rng, store, 16))
            sched.flush()
        sched.close()
        assert server.plan_cache.stats()["plan_hits"] > 0

    def test_quarantine_preserved_through_scheduler(self):
        server, store, users = fleet_server()
        clock = VirtualClock()
        sched = Scheduler(server, clock=clock)
        rng = np.random.default_rng(4)
        poison_user(store, users[0])
        t_bad = sched.submit(users[0], make_rows(rng, store, 8))
        t_ok = sched.submit(users[1], make_rows(rng, store, 8))
        sched.flush()
        sched.close()
        assert t_bad.status == "quarantined" and t_bad.prediction is None
        assert "IntegrityError" in t_bad.detail
        assert t_ok.status == "ok"
        assert users[0] in server.quarantined_users

    def test_batch_fault_isolation(self):
        server, store, users = fleet_server()
        clock = VirtualClock()
        faults = BatchFaults(fail_batches=(0,))
        sched = Scheduler(server, clock=clock, fault_hook=faults)
        rng = np.random.default_rng(5)
        t0 = sched.submit(users[0], make_rows(rng, store, 8))
        sched.flush()
        t1 = sched.submit(users[1], make_rows(rng, store, 8))
        sched.flush()
        sched.close()
        assert t0.status == "failed" and "InjectedCrash" in t0.detail
        assert t1.status == "ok"  # scheduler survived the poisoned batch
        assert sched.executor.stats()["n_failed_batches"] == 1

    def test_engine_timings_surface(self):
        server, store, users = fleet_server()
        sched = Scheduler(server, clock=VirtualClock())
        rng = np.random.default_rng(6)
        sched.submit(users[0], make_rows(rng, store, 8))
        sched.flush()
        sched.close()
        timings = server.stats()["engine_timings"]
        assert timings, "execute() must record at least one engine"
        for summary in timings.values():
            assert summary["count"] >= 1
            assert summary["p99_ms"] >= summary["p50_ms"] >= 0.0


# ---------------------------------------------------------------------------
# lifecycle driver
# ---------------------------------------------------------------------------

class TestLifecycleDriver:
    def test_load_aware_poll_window(self):
        server, store, users = fleet_server()
        clock = VirtualClock()
        d = LifecycleDriver(
            server, clock, poll_interval_s=1.0, max_poll_interval_s=4.0,
            low_load_rows=100,
        )
        d.tick(0.0, pending_rows=0)
        assert d.n_polls == 1 and d._next_poll == 1.0
        d.tick(0.5, pending_rows=0)  # inside window: no poll
        assert d.n_polls == 1
        d.tick(1.0, pending_rows=100)  # loaded: window stretches 2x
        assert d.n_polls == 2 and d._next_poll == 3.0
        d.tick(3.0, pending_rows=10**9)  # stretch is capped
        assert d._next_poll == 7.0

    def test_autonomous_recluster_waits_for_low_load(self):
        server, store, users = drifted_server()
        clock = VirtualClock()
        d = LifecycleDriver(
            server, clock, poll_interval_s=1.0, low_load_rows=64,
            migrate_users_per_s=1000.0, max_users_per_tick=1000,
        )
        assert drift_report(store)["recommend_recluster"]
        gen0 = store.generation
        d.tick(0.0, pending_rows=1000)  # high load: polls, defers
        assert d.n_polls == 1 and d.n_reclusters == 0
        assert store.generation == gen0
        d.tick(10.0, pending_rows=0)  # low-load gap: recluster fires
        assert d.n_reclusters == 1 and store.generation == gen0 + 1
        # migration budget was huge: done in one pass, journal committed
        while d.state == "migrating":
            clock.advance(1.0)
            d.tick(clock.now(), pending_rows=0)
        assert d.stats()["journal"]["state"] == "committed"
        assert drift_report(store)["n_pending_migration"] == 0
        assert not drift_report(store)["recommend_recluster"]

    def test_migration_rate_limit(self):
        server, store, users = drifted_server(n_users=12, late_fraction=0.5)
        clock = VirtualClock()
        d = LifecycleDriver(
            server, clock, poll_interval_s=0.1, low_load_rows=64,
            migrate_users_per_s=2.0, max_users_per_tick=1,
        )
        d.tick(0.0, pending_rows=0)
        assert d.state == "migrating"
        n_pending = d.stats()["n_pending_migration"]
        assert n_pending > 2
        # 1 second at 2 users/s but 1 user/tick cap, ticking every 0.5s
        d.tick(0.5, pending_rows=0)
        d.tick(1.0, pending_rows=0)
        assert d.n_migrated == 2  # rate limit respected, not all at once
        while d.state == "migrating":
            clock.advance(0.5)
            d.tick(clock.now(), pending_rows=0)
        assert d.n_migrated == n_pending

    def test_mixed_generation_serving_under_load(self):
        # the ISSUE 7 satellite test: stream requests through the
        # scheduler WHILE the driver reclusters and migrates; every
        # response must be bit-exact and no deadline blown beyond slack
        server, store, users = drifted_server()
        clock = VirtualClock()
        driver = LifecycleDriver(
            server, clock, poll_interval_s=0.2, low_load_rows=256,
            migrate_users_per_s=10.0, max_users_per_tick=1,
        )
        sched = Scheduler(
            server, clock=clock, queue=RequestQueue(slo_s=0.5),
            batcher=MicroBatcher(max_rows=128), lifecycle=driver,
        )
        rng = np.random.default_rng(8)
        gen0 = store.generation
        tickets = []
        saw_mixed = False
        for i in range(150):
            u = users[int(rng.integers(len(users)))]
            rows = make_rows(rng, store, 8)
            tickets.append((u, rows, sched.submit(u, rows)))
            clock.advance(0.05)
            sched.pump()
            if driver.state == "migrating":
                saw_mixed = True
        while driver.state == "migrating":
            clock.advance(0.1)
            sched.pump()
        sched.close()
        assert store.generation == gen0 + 1  # autonomous recluster ran
        assert saw_mixed  # requests were served MID-migration
        assert driver.n_migrated > 0
        silent_wrong = 0
        for u, rows, t in tickets:
            assert t.status == "ok", (t.status, t.detail)
            ref = predict_compressed(store.hydrate(u), rows)
            if not np.array_equal(t.prediction, ref):
                silent_wrong += 1
        assert silent_wrong == 0
        lat = sched.latency_stats(slack_s=0.25)
        assert lat["deadline_misses"] == 0

    def test_driver_excludes_quarantined_users(self):
        server, store, users = drifted_server()
        poison_user(store, users[0])
        server.serve_safe([(users[0], np.zeros(
            (1, store.shared.n_features), np.int32
        ))])
        assert users[0] in server.quarantined_users
        clock = VirtualClock()
        d = LifecycleDriver(server, clock, low_load_rows=64)
        d.tick(0.0, pending_rows=0)  # must not crash decoding the
        # poisoned delta; quarantined users sit out the accounting
        assert d.last_report["n_users"] == len(users) - 1
        # and a recluster is DEFERRED while anyone is quarantined — a
        # quarantined delta cannot be decoded, hence cannot be migrated
        assert d.n_reclusters == 0 and d.n_deferred == 1
        # repair the user (re-register a fresh forest, which also lifts
        # the quarantine via the version bump) and the next poll reclusters
        fixed = make_synthetic_fleet(
            1, "classification", n_trees=(4, 8), max_depth=4, seed=77
        )
        store.add_user(users[0], next(iter(fixed.values())))
        clock.advance(10.0)
        d.tick(clock.now(), pending_rows=0)
        assert d.n_reclusters == 1


# ---------------------------------------------------------------------------
# drift-report caching (the satellite bugfix)
# ---------------------------------------------------------------------------

class TestDriftReportCache:
    def test_memoized_on_store_version(self):
        server, store, users = drifted_server()
        r1 = drift_report(store)
        r2 = drift_report(store)
        assert r2 is r1  # identical object: full-report memo hit
        store.add_user(
            "fresh", make_synthetic_fleet(
                1, "classification", n_trees=(4, 8), max_depth=4, seed=42
            ).popitem()[1],
        )
        r3 = drift_report(store)
        assert r3 is not r1 and r3["n_users"] == r1["n_users"] + 1

    def test_distinct_args_not_conflated(self):
        server, store, users = drifted_server()
        r1 = drift_report(store, recluster_threshold=0.2)
        r2 = drift_report(store, recluster_threshold=0.9)
        assert r2 is not r1
        r3 = drift_report(store, exclude=(users[0],))
        assert r3["n_users"] == r1["n_users"] - 1

    def test_per_user_cache_sees_relabel_migration(self):
        # replace_delta_relabeled does NOT bump user_version — the
        # per-user memo must still notice the generation change
        from repro.store.lifecycle import recluster

        server, store, users = drifted_server()
        before = drift_report(store)
        assert before["fallback_user_fraction"] > 0
        recluster(store, mode="extend", seed=0)
        after = drift_report(store)
        assert after["codebook_generation"] == store.generation
        assert after["n_pending_migration"] == 0
        assert after["fallback_user_fraction"] == 0.0
        for u in users:
            assert (
                after["per_user"][u]["codebook_generation"]
                == store.generation
            )


# ---------------------------------------------------------------------------
# residency prefetch through the scheduler (ISSUE 10)
# ---------------------------------------------------------------------------

class TestResidencyPrefetch:
    def _durable_fleet(self, tmp_path, n_users=8):
        from repro.store import DurableStore

        forests = make_synthetic_fleet(
            n_users, "classification", n_trees=(4, 8), max_depth=4, seed=2
        )
        store0 = build_store(forests)
        base = str(tmp_path / "fleet")
        DurableStore.create(base, store0)
        return store0, base

    def _serving(self, base, budget, prefetch, clock):
        from repro.store import DurableStore, Prefetcher, attach_residency

        durable = DurableStore.open(base)
        store = durable.load_store(lazy=True)
        mgr = attach_residency(store, durable, budget_bytes=budget)
        server = ForestServer(store)
        pf = (
            Prefetcher(mgr, server=server, background=False)
            if prefetch else None
        )
        return Scheduler(server, clock, prefetcher=pf), mgr, store, server

    def test_prefetch_bit_identical_to_inline_and_hits(self, tmp_path):
        """Same trace, prefetch on vs off, both under VirtualClock: every
        response bit-identical, the budget held in both runs, and the
        prefetcher measurably warmed demoted users (hits > 0)."""
        store0, base = self._durable_fleet(tmp_path)
        sizes = {
            u: len(store0._deltas[u].to_bytes()) for u in store0.user_ids
        }
        budget = 3 * max(sizes.values())  # < fleet: demotions guaranteed
        assert budget < sum(sizes.values())

        def run(prefetch):
            clock = VirtualClock()
            sched, mgr, _, _ = self._serving(base, budget, prefetch, clock)
            rng = np.random.default_rng(4)
            users = sorted(store0.user_ids)
            tickets = []
            for _ in range(15):
                for _ in range(int(rng.integers(1, 4))):
                    u = users[int(rng.integers(len(users)))]
                    tickets.append(sched.submit(u, make_rows(rng, store0, 4)))
                clock.advance(0.3)
                sched.pump()
            sched.close()
            return tickets, mgr.stats()

        t_off, s_off = run(False)
        t_on, s_on = run(True)
        assert len(t_off) == len(t_on)
        for a, b in zip(t_off, t_on):
            assert a.status == b.status == "ok"
            assert np.array_equal(a.prediction, b.prediction)
        assert s_off["prefetch_requested"] == 0
        assert s_on["prefetch_hits"] > 0
        assert s_on["resident_bytes"] <= budget
        assert s_off["resident_bytes"] <= budget
        assert s_on["over_budget_events"] == 0

    def test_quarantined_user_never_prefetched(self, tmp_path):
        """A corrupt cold user quarantines through serve_safe (typed,
        never silent); once quarantined, later submissions must NOT
        prefetch them — the warm would just re-read poison."""
        from repro.runtime.chaos import DiskFaults
        from repro.store.durable import _LazyShard

        store0, base = self._durable_fleet(tmp_path)
        victim, healthy = sorted(store0.user_ids)[:2]
        clock = VirtualClock()
        sched, mgr, store, server = self._serving(
            base, 10**9, prefetch=True, clock=clock
        )
        durable = store._deltas._durable
        entry = durable.shard_for_user(victim)
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults().corrupt_region(path, off, min(length, 16))
        rng = np.random.default_rng(9)
        t_bad = sched.submit(victim, make_rows(rng, store0, 4))
        t_ok = sched.submit(healthy, make_rows(rng, store0, 4))
        sched.flush()
        assert t_bad.status == "quarantined" and t_bad.prediction is None
        assert t_ok.status == "ok"
        assert victim in server.quarantined_users
        st = mgr.stats()
        assert st["prefetch_errors"] == 1  # the pre-quarantine warm
        requested = st["prefetch_requested"]
        t_again = sched.submit(victim, make_rows(rng, store0, 4))
        sched.flush()
        assert t_again.status == "quarantined"
        assert mgr.stats()["prefetch_requested"] == requested  # filtered
        assert isinstance(dict.get(store._deltas, victim), _LazyShard)
        sched.close()

    def test_lifecycle_migrates_demoted_user_round_trip(self, tmp_path):
        """LifecycleDriver recluster + migration across a DEMOTED user:
        migration lazily reloads them, the relabeled delta is dirty, so
        the next demotion writes back — and every state transition keeps
        predictions bit-exact."""
        from repro.store import DurableStore, attach_residency

        initial, late = make_drifted_fleet(
            10, late_fraction=0.3, task="classification",
            n_trees=(4, 8), max_depth=4, seed=0,
        )
        store0 = build_store(initial)
        for u, f in late.items():
            store0.add_user(u, f)
        rng = np.random.default_rng(1)
        x = make_rows(rng, store0, 8)
        oracle = {u: store0.predict(u, x) for u in store0.user_ids}
        base = str(tmp_path / "fleet")
        DurableStore.create(base, store0)
        durable = DurableStore.open(base)
        store = durable.load_store(lazy=True)
        mgr = attach_residency(store, durable, budget_bytes=10**9)
        server = ForestServer(store)
        clock = VirtualClock()
        driver = LifecycleDriver(
            server, clock, poll_interval_s=0.1, low_load_rows=64,
            migrate_users_per_s=1e9, max_users_per_tick=1000,
        )
        victim = sorted(initial)[0]
        store.predict(victim, x)          # resident...
        assert mgr.demote(victim)         # ...then demoted (clean)
        assert drift_report(store)["recommend_recluster"]
        driver.tick(0.0, pending_rows=0)
        while driver.state == "migrating":
            clock.advance(1.0)
            driver.tick(clock.now(), pending_rows=0)
        assert driver.n_reclusters == 1
        for u, want in oracle.items():
            assert np.array_equal(store.predict(u, x), want), u
        # migration relabeled the victim: serialized bytes changed, so
        # demotion now requires a writeback before the placeholder swap
        assert mgr.demote(victim)
        st = mgr.stats()
        assert st["writebacks"] >= 1
        assert np.array_equal(store.predict(victim, x), oracle[victim])

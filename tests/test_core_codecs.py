"""Unit tests for the entropy-coding primitives (paper §2.2, §3.1)."""
import numpy as np
import pytest

from repro.core.arithmetic import ArithmeticCode
from repro.core.bitio import BitReader, BitWriter
from repro.core.huffman import HuffmanCode, entropy_bits
from repro.core.lz import lzw_decode_bits, lzw_encode_bits
from repro.core.zaks import zaks_decode, zaks_encode, zaks_is_valid

from conftest import random_tree


class TestBitIO:
    def test_roundtrip(self, rng):
        bits = rng.integers(0, 2, size=999)
        w = BitWriter()
        w.write_bitstring(bits)
        r = BitReader(w.getvalue())
        back = [r.read_bit() for _ in range(len(bits))]
        assert np.array_equal(back, bits)

    def test_write_bits_msb_first(self):
        w = BitWriter()
        w.write_bits(0b1011, 4)
        w.write_bits(0b0010, 4)
        assert w.getvalue() == bytes([0b10110010])


class TestHuffman:
    @pytest.mark.parametrize("b", [2, 3, 17, 64])
    def test_roundtrip(self, rng, b):
        freqs = rng.integers(1, 100, size=b)
        code = HuffmanCode.from_freqs(freqs)
        syms = rng.integers(0, b, size=500)
        assert np.array_equal(code.decode(code.encode(syms), 500), syms)

    def test_within_one_bit_of_entropy(self, rng):
        freqs = np.array([900, 50, 30, 15, 5], dtype=float)
        code = HuffmanCode.from_freqs(freqs)
        avg = code.encoded_bits(freqs) / freqs.sum()
        h = entropy_bits(freqs) / freqs.sum()
        assert h <= avg < h + 1

    def test_single_symbol_alphabet(self):
        code = HuffmanCode.from_freqs(np.array([0, 10, 0]))
        data = code.encode([1, 1, 1])
        assert np.array_equal(code.decode(data, 3), [1, 1, 1])

    def test_mismatched_distribution_still_lossless(self, rng):
        """Paper §5: Huffman stays lossless under a mismatched code Q, as
        long as Q covers the support."""
        q = np.array([1, 1, 1, 97], dtype=float)  # badly mismatched
        code = HuffmanCode.from_freqs(q)
        syms = rng.integers(0, 4, size=300)  # ~uniform P
        assert np.array_equal(code.decode(code.encode(syms), 300), syms)


class TestArithmetic:
    @pytest.mark.parametrize("b", [2, 5, 30])
    def test_roundtrip(self, rng, b):
        freqs = rng.integers(1, 50, size=b)
        code = ArithmeticCode(freqs)
        syms = rng.integers(0, b, size=400)
        assert np.array_equal(code.decode(code.encode(syms), 400), syms)

    def test_beats_huffman_on_skewed_binary(self, rng):
        """§4: arithmetic coding outperforms Huffman for skewed binary
        alphabets (Huffman is stuck at 1 bit/symbol)."""
        p = np.array([0.97, 0.03])
        syms = rng.choice(2, size=4000, p=p)
        freqs = np.bincount(syms, minlength=2)
        arith_bits = len(ArithmeticCode(freqs).encode(syms)) * 8
        huff_bits = len(HuffmanCode.from_freqs(freqs).encode(syms)) * 8
        assert arith_bits < 0.5 * huff_bits
        # within ~2 bits + byte padding of empirical entropy
        assert arith_bits <= entropy_bits(freqs) + 2 + 8


class TestLZW:
    def test_roundtrip(self, rng):
        bits = rng.integers(0, 2, size=5000).astype(np.uint8)
        assert np.array_equal(
            lzw_decode_bits(lzw_encode_bits(bits), 5000), bits
        )

    def test_compresses_repetitive_input(self):
        bits = np.tile(np.array([1, 1, 0, 1, 0, 0, 1, 0, 0, 0], np.uint8), 3000)
        payload = lzw_encode_bits(bits)
        # LZW rate approaches the (here: very low) entropy asymptotically
        assert len(payload) * 8 < 0.35 * len(bits)

    def test_empty(self):
        assert len(lzw_decode_bits(lzw_encode_bits(np.zeros(0, np.uint8)), 0)) == 0

    def test_kwkwk_case(self):
        # classic LZW corner: pattern that references the just-added entry
        bits = np.array([0, 0, 0, 0, 0, 0, 0], np.uint8)
        assert np.array_equal(lzw_decode_bits(lzw_encode_bits(bits), 7), bits)


class TestZaks:
    def test_roundtrip(self, rng):
        for _ in range(20):
            t = random_tree(rng)
            z = zaks_encode(t)
            assert zaks_is_valid(z)
            assert len(z) == t.n_nodes  # 2n+1 with n internal nodes
            left, right, leaf = zaks_decode(z)
            assert np.array_equal(left, t.children_left)
            assert np.array_equal(right, t.children_right)
            assert np.array_equal(leaf, t.is_leaf)

    def test_paper_example(self):
        """Fig. 1's sequence is a feasible Zaks sequence."""
        s = np.array([int(c) for c in "1111001001001111001000"], np.uint8)
        # paper prints the 22-bit prefix; a full sequence has 2n+1 bits, so
        # append the final 0 of the right-most missing subtree
        s = np.append(s, 0)
        assert zaks_is_valid(s)
        left, right, leaf = zaks_decode(s)
        assert (~leaf).sum() == 11  # 11 internal nodes

    def test_invalid_sequences_rejected(self):
        assert not zaks_is_valid(np.array([0, 1, 0], np.uint8))  # starts with 0
        assert not zaks_is_valid(np.array([1, 0, 0, 0], np.uint8))  # even len
        assert not zaks_is_valid(np.array([1, 0, 0, 1, 0], np.uint8))  # prefix hits
        assert zaks_is_valid(np.array([0], np.uint8))  # single leaf is a tree

"""End-to-end driver: pretrain a ~100M-parameter qwen-family LM for a few
hundred steps on the synthetic token pipeline, with fault-tolerant
checkpointing and (optionally) §7 gradient compression.

    PYTHONPATH=src python examples/lm_pretrain.py [--steps 300]
    PYTHONPATH=src python examples/lm_pretrain.py --grad-bits 4

Loss should drop from ~ln(V) toward the order-2 Markov structure of the
synthetic stream.  Re-running with the same --ckpt-dir resumes from the
latest checkpoint.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs.registry import get_config
from repro.data.tokens import Prefetcher, TokenDataConfig
from repro.launch.steps import make_train_step
from repro.launch.train import build_state
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import GradCompressionConfig
from repro.runtime import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-bits", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_pretrain")
    args = ap.parse_args()

    # ~100M params: qwen2.5 family, reduced
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b"),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=3072, vocab_size=16384, dtype="float32",
    )
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=30, total_steps=args.steps)
    grad_comp = GradCompressionConfig(bits=args.grad_bits) \
        if args.grad_bits else None

    state = build_state(cfg, opt_cfg, seed=0, grad_comp=grad_comp)
    n = sum(p.size for p in jax.tree.leaves(state["params"]))
    print(f"model: {n / 1e6:.1f}M params; "
          f"tokens/step {args.batch * args.seq}")

    data_cfg = TokenDataConfig(cfg.vocab_size, args.seq, args.batch, seed=0)
    step_jit = jax.jit(
        make_train_step(cfg, opt_cfg, remat=None, grad_comp=grad_comp),
        donate_argnums=(0, 1),
    )
    prefetch = Prefetcher(data_cfg, start_step=0)

    def step_fn(state, step):
        _s, batch = prefetch.get()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step_jit(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, {k: float(v) for k, v in m.items()}

    mgr = CheckpointManager(CheckpointConfig(args.ckpt_dir, codec=None))
    loop = TrainLoop(step_fn, mgr, save_every=100)
    t0 = time.time()
    loop.run(state, args.steps)
    losses = [m["loss"] for m in loop.metrics_log if "loss" in m]
    print(f"{len(losses)} steps in {time.time() - t0:.0f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0] - 0.5, "loss should drop visibly"
    print("ok")
    prefetch.close()


if __name__ == "__main__":
    main()

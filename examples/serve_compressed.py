"""Serving scenario: batched LM inference (prefill + decode) next to
forest prediction from compressed bytes — the two serving paths of the
framework.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import compress_forest, predict_compressed
from repro.data.tabular import TabularSpec, make_dataset
from repro.forest import fit_binner, predict_forest, to_compact_forest, train_forest
from repro.launch.steps import make_decode_step
from repro.models import init_params, prefill
from repro.serving import ForestServer


def lm_serving():
    cfg = get_config("rwkv6-1.6b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, prompt_len, gen = 4, 64, 24
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (b, prompt_len), 0, cfg.vocab_size)
    logits, cache = jax.jit(
        lambda p, t: prefill(cfg, p, t, max_len=prompt_len + gen)
    )(params, prompts)
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    tokens = jnp.argmax(logits, -1)
    t0 = time.time()
    for _ in range(gen):
        logits, cache = decode(params, tokens, cache)
        tokens = jnp.argmax(logits, -1)
    jax.block_until_ready(tokens)
    print(f"[lm] rwkv6 smoke: {b} seqs x {gen} tokens in "
          f"{time.time() - t0:.2f}s (O(1) state decode)")


def forest_serving():
    spec = TabularSpec("serve", 3000, 10, "classification", 2, 2)
    x, y, cat = make_dataset(spec, seed=0)
    binner = fit_binner(x, categorical=cat, n_bins=32)
    model = train_forest(x, y, binner, n_trees=40, max_depth=8,
                         task="classification", n_classes=2)
    forest = to_compact_forest(model)
    comp = compress_forest(forest)
    xb = binner.transform(x[:500])

    # the session API (ISSUE 4): plan once, execute per row batch — the
    # plan carries the engine choice, and repeated batch signatures reuse
    # the arena-gathered pack across calls
    server = ForestServer.from_forest(comp)
    plan = server.plan([("forest", xb)])
    t0 = time.time()
    pred = server.execute(plan, [xb])[0]
    t_cold = time.time() - t0
    t0 = time.time()
    pred_warm = server.execute(plan, [xb])[0]  # plan-cache hot
    t_warm = time.time() - t0
    ref = predict_forest(model, x[:500])
    assert (pred == ref).all() and (pred_warm == ref).all()
    assert (predict_compressed(comp, xb) == ref).all()  # reference oracle
    blob = len(comp.to_bytes())
    pc = server.stats()["plan_cache"]
    print(f"[forest] 500 predictions from {blob} compressed bytes via "
          f"engine={plan.engine.name}: cold {t_cold:.2f}s, warm "
          f"{t_warm * 1e3:.0f}ms (pack hits {pc['pack_hits']}) — "
          f"identical to the uncompressed forest")


if __name__ == "__main__":
    lm_serving()
    forest_serving()

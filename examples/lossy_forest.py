"""§7 walkthrough: the rate-distortion trade-off on a regression forest —
sweep fit-quantization bits and subsampled trees, verify the theory's
predictions (distortion ~ sigma^2/|A0|; size linear in |A0|).

    PYTHONPATH=src python examples/lossy_forest.py
"""
import numpy as np

from repro.core import (
    compress_forest,
    quantize_fits,
    subsample_trees,
)
from repro.core.lossy import estimate_sigma2_per_obs
from repro.core.compressed_predict import predict_compressed
from repro.data.tabular import spec_by_name, make_dataset, scaled
from repro.forest import fit_binner, per_tree_predictions, to_compact_forest, train_forest


def main() -> None:
    spec = scaled(spec_by_name("airfoil_reg"), 1503)
    x, y, cat = make_dataset(spec, seed=0)
    n_test = len(x) // 5
    x_tr, x_te, y_tr, y_te = x[:-n_test], x[-n_test:], y[:-n_test], y[-n_test:]
    binner = fit_binner(x_tr, categorical=cat, n_bins=64)
    model = train_forest(x_tr, y_tr, binner, n_trees=60, max_depth=8,
                         task="regression", seed=0)
    forest = to_compact_forest(model)
    xb_te = binner.transform(x_te)

    # sigma^2 of the per-tree error (the theory's knob) — estimated on the
    # TEST predictions, since that's where the MSE delta is measured
    per_tree = per_tree_predictions(model, x_te)
    sigma2 = estimate_sigma2_per_obs(per_tree)
    print(f"sigma^2 (per-tree error variance) = {sigma2:.4f}")

    comp = compress_forest(forest)
    base_mse = float(np.mean(
        (predict_compressed(comp, xb_te) - y_te) ** 2))
    base_kb = comp.size_report()["total_serialized"] / 1e3
    print(f"lossless: MSE {base_mse:.4f} @ {base_kb:.1f} KB")

    print("\nfit quantization (b bits):")
    for b in (4, 6, 8, 10):
        qf, max_err = quantize_fits(forest, b)
        c = compress_forest(qf)
        mse = float(np.mean((predict_compressed(c, xb_te) - y_te) ** 2))
        kb = c.size_report()["total_serialized"] / 1e3
        print(f"  b={b:>2d}: MSE {mse:.4f} (+{mse - base_mse:+.4f}) "
              f"@ {kb:6.1f} KB  max_fit_err {max_err:.5f}")

    print("\ntree subsampling (theory: ΔMSE ≈ sigma²/|A0| - sigma²/|A|):")
    for keep in (10, 20, 40, 60):
        sf = subsample_trees(forest, keep, seed=1)
        c = compress_forest(sf)
        mse = float(np.mean((predict_compressed(c, xb_te) - y_te) ** 2))
        kb = c.size_report()["total_serialized"] / 1e3
        pred = sigma2 / keep - sigma2 / forest.n_trees
        print(f"  |A0|={keep:>3d}: MSE {mse:.4f} (Δ {mse - base_mse:+.4f}, "
              f"theory +{pred:.4f}) @ {kb:6.1f} KB")


if __name__ == "__main__":
    main()

"""Beyond-paper scenario: entropy-coded checkpoints + preemption-proof
training (the paper's codec machinery keeping a training run's storage
footprint down while surviving simulated node failures).

    PYTHONPATH=src python examples/compressed_checkpointing.py
"""
import dataclasses
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs.registry import get_config
from repro.data.tokens import TokenDataConfig, synth_batch
from repro.launch.steps import make_train_step
from repro.launch.train import build_state
from repro.optim.adamw import AdamWConfig
from repro.runtime import Preemption, PreemptionSchedule, TrainLoop


def main() -> None:
    cfg = get_config("qwen3-4b").smoke()
    cfg = dataclasses.replace(cfg, dtype="bfloat16")  # 16-bit: lossless split
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    data_cfg = TokenDataConfig(cfg.vocab_size, 64, 4, seed=0)
    step_jit = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in synth_batch(data_cfg, step).items()}
        params, opt, m = step_jit(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, {k: float(v) for k, v in m.items()}

    workdir = tempfile.mkdtemp(prefix="repro_ckpt_demo_")
    try:
        # --- run A: no failures, plain npz checkpoints ------------------
        mgr_a = CheckpointManager(CheckpointConfig(f"{workdir}/a"))
        loop_a = TrainLoop(step_fn, mgr_a, save_every=10)
        final_a = loop_a.run(build_state(cfg, opt_cfg, seed=0), 30)

        # --- run B: preempted twice, ENTROPY-CODED checkpoints ----------
        mgr_b = CheckpointManager(
            CheckpointConfig(f"{workdir}/b", codec="lossless")
        )
        loop_b = TrainLoop(
            step_fn, mgr_b, save_every=10,
            preemption=PreemptionSchedule(fail_at=(7, 23)),
        )
        final_b = loop_b.run(build_state(cfg, opt_cfg, seed=0), 30)
        print(f"run B survived {loop_b.restarts} preemptions")

        # bit-identical final state despite failures + codec
        leaves_a = jax.tree.leaves(final_a["params"])
        leaves_b = jax.tree.leaves(final_b["params"])
        same = all(
            np.array_equal(np.asarray(x), np.asarray(y))
            for x, y in zip(leaves_a, leaves_b)
        )
        print(f"final params identical to uninterrupted run: {same}")
        assert same

        # storage footprint comparison
        def du(path):
            return sum(
                os.path.getsize(os.path.join(r, f))
                for r, _d, fs in os.walk(path) for f in fs
            )

        raw, coded = du(f"{workdir}/a"), du(f"{workdir}/b")
        print(f"checkpoint dir: npz {raw / 1e6:.2f} MB vs "
              f"entropy-coded {coded / 1e6:.2f} MB "
              f"({raw / coded:.2f}x smaller)")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()

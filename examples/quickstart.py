"""Quickstart: the paper's pipeline end to end in ~30 seconds.

Train a random forest (JAX histogram CART) -> compress it losslessly
(Algorithm 1) -> predict STRAIGHT FROM THE COMPRESSED BYTES (§5) ->
decompress and verify a perfect reconstruction -> apply the §7 lossy
knobs.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    CompressedForest,
    compress_forest,
    decompress_forest,
    predict_compressed,
    quantize_fits,
    subsample_trees,
)
from repro.data.tabular import TabularSpec, make_dataset
from repro.forest import (
    fit_binner,
    light_compress,
    predict_forest,
    standard_compress,
    to_compact_forest,
    train_forest,
)


def main() -> None:
    # 1. data + forest (the substrate the paper assumes)
    spec = TabularSpec("demo", 2000, 8, "classification", n_classes=2,
                       n_categorical=2)
    x, y, categorical = make_dataset(spec, seed=0)
    binner = fit_binner(x, categorical=categorical, n_bins=32)
    model = train_forest(x, y, binner, n_trees=50, max_depth=8,
                         task="classification", n_classes=2, seed=0)
    acc = (predict_forest(model, x) == y).mean()
    print(f"forest: 50 trees, train accuracy {acc:.3f}")

    # 2. lossless compression (Algorithm 1)
    forest = to_compact_forest(model)
    comp = compress_forest(forest)
    blob = comp.to_bytes()
    sizes = comp.size_report()
    print(f"standard pickle+deflate: {len(standard_compress(forest))} B")
    print(f"light (pred-only+deflate): {len(light_compress(forest))} B")
    print(f"ours: {len(blob)} B  "
          f"(structure {sizes['structure']}, names {sizes['var_names']}, "
          f"splits {sizes['split_values']}, fits {sizes['fits']}, "
          f"dict {sizes['dictionaries']})")

    # 3. prediction from the compressed format (§5) — no decompression
    comp2 = CompressedForest.from_bytes(blob)
    xb = binner.transform(x[:200])
    pred_comp = predict_compressed(comp2, xb)
    pred_ref = predict_forest(model, x[:200])
    assert (pred_comp == pred_ref).all()
    print("predict-from-compressed == original forest predictions ✓")

    # 4. perfect reconstruction
    assert decompress_forest(comp2).equals(forest)
    print("decompressed forest is bit-identical ✓")

    # 5. lossy knobs (§7): subsample trees, then recompress
    small = subsample_trees(forest, 20, seed=1)
    comp_small = compress_forest(small)
    print(f"lossy: 20/50 trees -> {len(comp_small.to_bytes())} B")


if __name__ == "__main__":
    main()

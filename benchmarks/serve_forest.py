"""Fused decode->predict serving benchmark (ISSUE 1 tentpole measurement).

Measures, for a quickstart-sized trained forest (>=100 trees, >=5k rows by
default), on both tasks:

* decode: seed bit-at-a-time baseline vs the table-driven vectorized decoder
  (MB/s over the compressed payload);
* predict_compressed: the seed implementation replica (``engine="bitwise"``:
  per-bit dict-lookup Huffman + reference LZW/Zaks/arithmetic decoders) vs
  the rebuilt path, cold (decode + traverse) and warm (decode-once serving
  steady state — the paper's subscriber device holds ONE compressed forest
  and answers many requests);
* the Pallas serving kernel: fused-aggregation parity vs the (T, N) kernel's
  reduced result, and streamed decode->predict throughput at several batch
  sizes.

Writes machine-readable results to BENCH_serve_forest.json (repo root).

    PYTHONPATH=src python benchmarks/serve_forest.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent))

from common import train_compact  # noqa: E402

from repro.core import CompressedForest, compress_forest, predict_compressed  # noqa: E402
from repro.core.compressed_predict import iter_trees  # noqa: E402
from repro.data.tabular import TabularSpec, make_dataset  # noqa: E402


def best_of(fn, repeats: int) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.time()
        fn()
        ts.append(time.time() - t0)
    return min(ts)


def bench_task(task: str, n_trees: int, rows: int, depth: int,
               repeats: int) -> dict:
    import jax

    from repro.kernels.tree_predict.ref import forest_predict_reference
    from repro.kernels.tree_predict.tree_predict import (
        forest_predict,
        forest_predict_agg,
    )
    from repro.launch.serve_forest import iter_heap_tiles
    from repro.serving import ForestServer

    spec = TabularSpec(f"serve-{task}", rows, 8, task, 2, 2)
    forest, model, _ = train_compact(
        spec, n_trees=n_trees, max_depth=depth, seed=0
    )
    blob = compress_forest(forest).to_bytes()
    comp = CompressedForest.from_bytes(blob)
    xb = model.binner.transform(make_dataset(spec, seed=0)[0])[:rows]
    n_nodes = sum(t.n_nodes for t in forest.trees)

    # ---- decode sweep ----------------------------------------------------
    t_dec_seed = best_of(
        lambda: list(iter_trees(comp, engine="bitwise")), min(2, repeats)
    )
    t_dec = best_of(lambda: list(iter_trees(comp)), repeats)
    comp_mb = len(blob) / 1e6

    # ---- predict_compressed: seed replica vs cold vs warm -----------------
    p_seed = predict_compressed(comp, xb, engine="bitwise")
    t_seed = best_of(
        lambda: predict_compressed(comp, xb, engine="bitwise"),
        min(2, repeats),
    )
    predict_compressed(CompressedForest.from_bytes(blob), xb)  # jit warm-up
    t_cold = best_of(
        lambda: predict_compressed(CompressedForest.from_bytes(blob), xb),
        repeats,
    )
    warm = CompressedForest.from_bytes(blob)
    p_new = predict_compressed(warm, xb)
    t_warm = best_of(lambda: predict_compressed(warm, xb), repeats)
    bit_exact = bool(np.array_equal(p_seed, p_new))

    # ---- Pallas kernels: agg parity + streamed serving throughput ---------
    import jax.numpy as jnp

    feature, threshold, fit, is_internal = next(
        iter_heap_tiles(comp, block_trees=min(n_trees, 32))
    )
    args = (
        jnp.asarray(xb[:512], jnp.int32), jnp.asarray(feature),
        jnp.asarray(threshold), jnp.asarray(fit), jnp.asarray(is_internal),
    )
    per_tree = np.asarray(forest_predict(*args, max_depth=comp.max_depth))
    agg = np.asarray(forest_predict_agg(*args, max_depth=comp.max_depth))
    reduced = per_tree.sum(0)
    agg_err = float(np.max(np.abs(agg - reduced)))
    agg_rel_err = float(
        np.max(np.abs(agg - reduced) / (np.abs(reduced) + 1e-9))
    )
    ref = np.asarray(
        forest_predict_reference(*args, comp.max_depth)
    )
    kernel_err = float(np.max(np.abs(per_tree - ref)))

    serve = {}
    session = ForestServer.from_forest(comp)
    for batch in sorted({min(512, rows), min(2048, rows), rows}):
        session.predict(xb[:batch])  # compile + warm
        t = best_of(
            lambda b=batch: session.predict(xb[:b]), repeats
        )
        serve[str(batch)] = {
            "ms": round(t * 1e3, 2),
            "rows_per_s": round(batch / t, 1),
        }

    return {
        "task": task,
        "n_trees": n_trees,
        "max_depth": comp.max_depth,
        "rows": rows,
        "total_nodes": n_nodes,
        "compressed_bytes": len(blob),
        "decode": {
            "seed_ms": round(t_dec_seed * 1e3, 2),
            "table_ms": round(t_dec * 1e3, 2),
            "speedup": round(t_dec_seed / t_dec, 2),
            "table_MB_per_s": round(comp_mb / t_dec, 3),
            "nodes_per_s": round(n_nodes / t_dec, 1),
        },
        "predict_compressed": {
            "seed_ms": round(t_seed * 1e3, 2),
            "cold_ms": round(t_cold * 1e3, 2),
            "warm_ms": round(t_warm * 1e3, 2),
            "speedup_cold": round(t_seed / t_cold, 2),
            "speedup_warm": round(t_seed / t_warm, 2),
            "bit_exact": bit_exact,
        },
        "kernel": {
            "backend": jax.default_backend(),
            "agg_vs_per_tree_reduced_max_abs_err": agg_err,
            "agg_vs_per_tree_reduced_max_rel_err": agg_rel_err,
            "per_tree_vs_reference_max_abs_err": kernel_err,
            "streamed_serve": serve,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small forest for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        n_trees, rows, depth, repeats = 24, 1200, 6, 1
    else:
        n_trees, rows, depth, repeats = 100, 5000, 8, 7
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serve_forest.json"
    )
    results = {
        "benchmark": "serve_forest",
        "quick": bool(args.quick),
        "config": {"n_trees": n_trees, "rows": rows, "max_depth": depth},
        "tasks": [
            bench_task("classification", n_trees, rows, depth, repeats),
            bench_task("regression", n_trees, rows, depth, repeats),
        ],
    }
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

"""Roofline report: turns experiments/dryrun/*.json into the
EXPERIMENTS.md tables (per arch x shape x mesh: three terms, bottleneck,
useful-FLOP fraction).

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path


def load(dir_: str):
    rows = []
    for f in sorted(glob.glob(str(Path(dir_) / "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def markdown_table(rows, multi_pod: bool) -> str:
    out = [
        "| arch | shape | peak GiB/dev | compute s | memory s | "
        "collective s | bottleneck | useful FLOP frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok" or r["multi_pod"] != multi_pod:
            continue
        t = r["roofline_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['memory']['peak_bytes'] / 2**30:.2f} "
            f"| {t['compute']:.3g} | {t['memory']:.3g} "
            f"| {t['collective']:.3g} | {r['bottleneck']} "
            f"| {r['useful_flop_fraction']:.3f} |"
        )
    return "\n".join(out)


def summary(rows) -> dict:
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    failed = [r for r in rows if r["status"] == "failed"]
    worst = sorted(
        (r for r in ok if not r["multi_pod"]),
        key=lambda r: r["useful_flop_fraction"],
    )
    coll = sorted(
        (r for r in ok if not r["multi_pod"]),
        key=lambda r: -r["roofline_s"]["collective"]
        / max(sum(r["roofline_s"].values()), 1e-12),
    )
    return {
        "ok": len(ok),
        "skipped": len(skipped),
        "failed": len(failed),
        "worst_useful_fraction": [
            (r["cell"], round(r["useful_flop_fraction"], 4)) for r in worst[:5]
        ],
        "most_collective_bound": [
            (r["cell"], round(r["roofline_s"]["collective"], 3)) for r in coll[:5]
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    if not rows:
        print(f"no dry-run records in {args.dir}; run "
              "`python -m repro.launch.dryrun --all` first")
        return
    if args.markdown:
        print("### Single-pod (16x16 = 256 chips)\n")
        print(markdown_table(rows, False))
        print("\n### Multi-pod (2x16x16 = 512 chips)\n")
        print(markdown_table(rows, True))
        return
    s = summary(rows)
    print(json.dumps(s, indent=1))


if __name__ == "__main__":
    main()

"""Durable shard-store benchmark (ISSUE 8 acceptance measurement).

Puts numbers on the durability tentpole, and in ``--smoke`` mode ASSERTS
its acceptance criteria (the CI `durable` job runs exactly that):

* **open latency vs fleet size** — ``DurableStore.open`` +
  ``load_store`` lazy vs eager: the lazy path reads only the manifest +
  codebooks, so its cost must stay flat as the fleet grows (the first
  rung of the disk -> host RAM -> HBM residency ladder);
* **crash sweep** — a commit (replace + add + remove users) and a
  compaction are killed at EVERY write step (``InjectedCrash`` via
  ``CrashSchedule``); each crash point must reopen to a bit-exact fleet
  (pre- or post-commit, never torn) and a retried run must converge to
  the post state;
* **scrub + repair** — ``Scrubber`` throughput over a healthy fleet
  (MB/s), then one injected single-shard corruption per slab: every one
  must repair from parity bit-exactly, with per-repair wall time;
* **serving auto-repair** — ``serve_safe`` + ``attach_auto_repair`` over
  a corrupted-on-disk user: served ``ok`` with predictions bit-equal to
  a clean fleet's; a double-faulted user stays quarantined.  The silent-
  wrong count across every section must be 0.

Writes machine-readable results to BENCH_durable.json (repo root).

    PYTHONPATH=src python benchmarks/durable_bench.py [--smoke|--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.core.framing import IntegrityError, UnrepairableError
from repro.runtime.chaos import (
    CrashSchedule,
    DiskFaults,
    InjectedCrash,
    record_steps,
)
from repro.serving import ForestServer
from repro.store import (
    DurableStore,
    Scrubber,
    attach_auto_repair,
    build_store,
    make_request_batch,
    make_synthetic_fleet,
)


def _build(n_users: int, seed: int):
    fleet = make_synthetic_fleet(
        n_users=n_users, d=6, n_bins=12, seed=seed, n_trees=(4, 8),
        max_depth=4,
    )
    return build_store(fleet, seed=0)


def _ref_bytes(store) -> dict:
    return {u: store.delta(u).to_bytes() for u in store.user_ids}


def _fleet_bit_exact(durable, ref: dict) -> bool:
    loaded = durable.load_store(lazy=False)
    if set(loaded.user_ids) != set(ref):
        return False
    return all(loaded.delta(u).to_bytes() == ref[u] for u in ref)


# ---------------------------------------------------------------------------
# open latency vs fleet size
# ---------------------------------------------------------------------------

def bench_open_latency(fleet_sizes: list[int], seed: int = 3) -> list[dict]:
    out = []
    for n in fleet_sizes:
        store = _build(n, seed)
        ref = _ref_bytes(store)
        root = tempfile.mkdtemp(prefix="durable_bench_")
        try:
            base = f"{root}/fleet"
            t0 = time.time()
            durable = DurableStore.create(base, store)
            create_ms = (time.time() - t0) * 1e3

            t0 = time.time()
            lazy = DurableStore.open(base).load_store(lazy=True)
            open_lazy_ms = (time.time() - t0) * 1e3
            u0 = sorted(ref)[0]
            t0 = time.time()
            first = lazy.delta(u0)
            first_touch_ms = (time.time() - t0) * 1e3
            lazy_exact = first.to_bytes() == ref[u0]

            t0 = time.time()
            eager = DurableStore.open(base).load_store(lazy=False)
            open_eager_ms = (time.time() - t0) * 1e3
            eager_exact = all(
                eager.delta(u).to_bytes() == ref[u] for u in ref
            )
            stats = durable.stats()
            out.append({
                "n_users": n,
                "live_bytes": stats["live_bytes"],
                "n_slabs": stats["n_slabs"],
                "create_ms": round(create_ms, 2),
                "open_lazy_ms": round(open_lazy_ms, 2),
                "open_eager_ms": round(open_eager_ms, 2),
                "first_touch_ms": round(first_touch_ms, 3),
                "lazy_bit_exact": bool(lazy_exact),
                "eager_bit_exact": bool(eager_exact),
            })
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return out


# ---------------------------------------------------------------------------
# crash sweep: kill at every write / compaction step
# ---------------------------------------------------------------------------

def bench_crash_sweep(n_users: int, seed: int = 5) -> dict:
    store = _build(n_users, seed)
    ref = _ref_bytes(store)
    users = sorted(ref)
    root = tempfile.mkdtemp(prefix="durable_bench_")
    try:
        base = f"{root}/fleet"
        # small slabs so commits span several slab+parity write steps
        d0 = DurableStore.create(base, store, slab_shards=4)
        # pre-seed garbage for the compaction sweep
        d0.put_delta(users[0], store.delta(users[0]))
        d0.remove_user(users[-1])
        d0.commit()
        pre = dict(ref)
        del pre[users[-1]]
        post = dict(pre)
        post["late_user"] = ref[users[1]]

        def commit_op(on_step):
            d = DurableStore.open(base)
            d.put_delta_bytes("late_user", ref[users[1]],
                              store.delta(users[1]).codebook_generation)
            d.commit(on_step=on_step)

        def compact_op(on_step):
            DurableStore.open(base).compact(on_step=on_step)

        snap = f"{root}/snap"
        shutil.copytree(base, snap)
        results = {}
        for op_name, op, pre_state, post_state in (
            ("commit", commit_op, pre, post),
            ("compact", compact_op, pre, pre),
        ):
            shutil.rmtree(base)
            shutil.copytree(snap, base)
            steps = record_steps(op)
            points = []
            all_exact = True
            for i, name in enumerate(steps):
                shutil.rmtree(base)
                shutil.copytree(snap, base)
                crashed = False
                try:
                    op(CrashSchedule(fail_at=(i,)))
                except InjectedCrash:
                    crashed = True
                t0 = time.time()
                d = DurableStore.open(base)
                recover_ms = (time.time() - t0) * 1e3
                is_pre = _fleet_bit_exact(d, pre_state)
                is_post = _fleet_bit_exact(d, post_state)
                exact = is_pre or is_post
                # retrying the op after recovery must converge to POST
                op(lambda _s: None)
                converged = _fleet_bit_exact(DurableStore.open(base),
                                             post_state)
                all_exact = all_exact and crashed and exact and converged
                points.append({
                    "step": name,
                    "state": "post" if is_post else
                             ("pre" if is_pre else "TORN"),
                    "recover_ms": round(recover_ms, 2),
                    "bit_exact": bool(exact),
                    "retry_converges": bool(converged),
                })
            results[op_name] = {
                "n_steps": len(steps),
                "steps": steps,
                "all_crash_points_bit_exact": bool(all_exact),
                "crash_points": points,
            }
        return results
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# scrub throughput + parity repair
# ---------------------------------------------------------------------------

def bench_scrub_repair(n_users: int, seed: int = 7) -> dict:
    store = _build(n_users, seed)
    ref = _ref_bytes(store)
    root = tempfile.mkdtemp(prefix="durable_bench_")
    try:
        base = f"{root}/fleet"
        durable = DurableStore.create(base, store)

        # clean-scrub throughput
        scrubber = Scrubber(durable)
        t0 = time.time()
        clean = scrubber.scrub_all()
        dt = time.time() - t0
        scrub_mb_per_s = (scrubber.bytes_scanned / 1e6) / max(dt, 1e-9)

        # one injected single-shard corruption per slab; each must repair
        faults = DiskFaults(seed=seed)
        victims = []
        for slab in durable.manifest.slabs:
            entry = max(slab.shards, key=lambda e: e.length)
            path, off, length = durable.shard_location(entry.shard_id)
            faults.corrupt_region(path, off, min(length, 64))
            victims.append(entry.shard_id)
        repair_ms = []
        for sid in victims:
            t0 = time.time()
            durable.read_shard(sid, repair=True)
            repair_ms.append((time.time() - t0) * 1e3)
        bit_exact_after = _fleet_bit_exact(durable, ref)

        # a residual scrub pass must now find a healthy fleet
        residual = Scrubber(durable).scrub_all()
        return {
            "n_users": n_users,
            "bytes_scanned": scrubber.bytes_scanned,
            "clean_pass": clean,
            "scrub_mb_per_s": round(scrub_mb_per_s, 2),
            "n_injected": len(victims),
            "n_repaired": durable.n_repairs,
            "repair_ms_mean": round(float(np.mean(repair_ms)), 3),
            "repair_ms_max": round(float(np.max(repair_ms)), 3),
            "bit_exact_after_repair": bool(bit_exact_after),
            "residual_pass": residual,
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# serving auto-repair (quarantine -> repair -> verify -> release)
# ---------------------------------------------------------------------------

def bench_serve_repair(n_users: int, rows: int, seed: int = 9) -> dict:
    store = _build(n_users, seed)
    users = sorted(store.user_ids)
    root = tempfile.mkdtemp(prefix="durable_bench_")
    try:
        base = f"{root}/fleet"
        # small slabs so the fleet spans several parity groups — the
        # repairable single fault and the unrepairable double fault must
        # live in DIFFERENT groups
        durable = DurableStore.create(base, store, slab_shards=4)

        # corrupt one user's shard on disk (single fault: repairable)
        victim = users[0]
        entry = durable.shard_for_user(victim)
        victim_slab = next(
            s.slab_id for s in durable.manifest.slabs
            if any(e.shard_id == entry.shard_id for e in s.shards)
        )
        path, off, length = durable.shard_location(entry.shard_id)
        DiskFaults(seed=seed).corrupt_region(path, off, min(length, 64))
        # double-fault a pair of users in another slab group
        # (unrepairable: must stay quarantined)
        doomed = []
        for slab in durable.manifest.slabs:
            if slab.slab_id == victim_slab:
                continue
            delta_shards = [e for e in slab.shards if e.name]
            if len(delta_shards) >= 2:
                for e in delta_shards[:2]:
                    p, o, ln = durable.shard_location(e.shard_id)
                    DiskFaults(seed=seed).corrupt_region(p, o, min(ln, 64))
                    doomed.append(e.name)
                break

        server = ForestServer(durable.load_store(lazy=True))
        attach_auto_repair(server, durable)
        clean = ForestServer(store)
        requests = make_request_batch(store, n_requests=2 * n_users,
                                      rows_per_request=rows, seed=seed)
        t0 = time.time()
        statuses = server.serve_safe(requests, engine="simple")
        serve_ms = (time.time() - t0) * 1e3
        silent_wrong = parity_exact = n_ok = n_quarantined = 0
        for s, (u, x) in zip(statuses, requests):
            if s.status == "ok":
                n_ok += 1
                want = clean.serve([(u, x)], engine="simple")[0]
                if np.array_equal(s.prediction, want):
                    parity_exact += 1
                else:
                    silent_wrong += 1
            else:
                n_quarantined += 1
                if s.user_id not in doomed:
                    silent_wrong += 1  # repairable user not released
        health = server.stats()["health"]
        return {
            "n_users": n_users,
            "n_requests": len(requests),
            "victim_repaired": health["repairs"] >= 1,
            "doomed_users": sorted(set(doomed)),
            "n_ok": n_ok,
            "n_quarantined": n_quarantined,
            "quarantined_users": server.quarantined_users,
            "parity_exact_requests": parity_exact,
            "serve_ms": round(serve_ms, 2),
            "repair_attempts": health["repair_attempts"],
            "repairs": health["repairs"],
            "last_repair_error": health["last_repair_error"],
            "silent_wrong": silent_wrong,
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------


def _assert_smoke(results: dict) -> None:
    """The CI acceptance gate (ISSUE 8): every crash point recovers
    bit-exact, scrub repairs every injected corruption, and the silent-
    wrong count across all sections is 0."""
    for op_name, sweep in results["crash_sweep"].items():
        assert sweep["n_steps"] > 0, op_name
        assert sweep["all_crash_points_bit_exact"], (op_name, sweep)
    scrub = results["scrub_repair"]
    assert scrub["n_injected"] > 0
    assert scrub["n_repaired"] == scrub["n_injected"], scrub
    assert scrub["bit_exact_after_repair"], scrub
    assert scrub["clean_pass"]["unrepairable"] == 0, scrub
    for f in results["open_latency"]:
        assert f["lazy_bit_exact"] and f["eager_bit_exact"], f
    serve = results["serve_repair"]
    assert serve["victim_repaired"], serve
    assert set(serve["quarantined_users"]) == set(serve["doomed_users"]), serve
    assert results["silent_wrong_total"] == 0, results
    print("durable smoke ok")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fleets + hard acceptance asserts (CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small fleets, no asserts")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke or args.quick:
        fleet_sizes, crash_users, scrub_users, serve_users, rows = \
            [6, 16], 5, 8, 6, 32
    else:
        fleet_sizes, crash_users, scrub_users, serve_users, rows = \
            [10, 40, 120], 10, 40, 12, 128

    results: dict = {
        "benchmark": "durable",
        "quick": bool(args.smoke or args.quick),
        "open_latency": bench_open_latency(fleet_sizes),
        "crash_sweep": bench_crash_sweep(crash_users),
        "scrub_repair": bench_scrub_repair(scrub_users),
        "serve_repair": bench_serve_repair(serve_users, rows),
    }
    results["silent_wrong_total"] = (
        results["serve_repair"]["silent_wrong"]
        + sum(
            0 if p["bit_exact"] else 1
            for sweep in results["crash_sweep"].values()
            for p in sweep["crash_points"]
        )
        + (0 if results["scrub_repair"]["bit_exact_after_repair"] else 1)
    )
    if args.smoke:
        _assert_smoke(results)

    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_durable.json"
    )
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

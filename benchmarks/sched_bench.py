"""Continuous-batching scheduler benchmark (ISSUE 7 acceptance
measurement).

Three phases on a 100-user synthetic fleet:

* **throughput** — a seeded Poisson trace is run through the scheduler
  once to RECORD the micro-batches it forms; then the same batches are
  timed through (a) the scheduler (submit + flush, pipelined executor,
  plan overlap on) and (b) direct ``ForestServer.serve`` calls, one per
  recorded batch — equal batch sizes by construction.  Acceptance:
  scheduled serving sustains at least the PR 4 session rows/s
  (``sched_vs_direct >= 1`` up to timer noise — the scheduler adds
  queueing + batching bookkeeping, the overlap gives it back);
* **latency** — the same trace replayed OPEN-LOOP under the wall clock
  (arrivals paced, deadline trigger live): arrival-to-completion p50 /
  p99 and the fraction of requests inside the SLO;
* **lifecycle** — a drifted fleet served under the VIRTUAL clock while
  an attached ``LifecycleDriver`` autonomously reclusters and migrates
  rate-limited; every response is then checked bit-exact against
  per-user ``predict_compressed`` (``silent_wrong_total`` must be 0,
  ``n_reclusters`` must be >= 1).

``--smoke`` (the CI gate) shrinks the trace, keeps the 100-user fleet,
and ASSERTS: every scheduled prediction bit-exact vs direct
``ForestServer.serve``, and plan-cache hit rate > 0 across the replayed
trace.

Writes machine-readable results to BENCH_sched.json (repo root).

    PYTHONPATH=src python benchmarks/sched_bench.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from common import poisson_trace


def build_fleet_server(n_users, task, seed, drift=False):
    from repro.serving import ForestServer
    from repro.store import build_store, make_synthetic_fleet
    from repro.store.fleet import make_drifted_fleet

    if drift:
        initial, late = make_drifted_fleet(
            n_users, late_fraction=0.3, task=task,
            n_trees=(4, 8), max_depth=4, seed=seed,
        )
        store = build_store(initial)
        for u, f in late.items():
            store.add_user(u, f)
        fleet = {**initial, **late}
    else:
        fleet = make_synthetic_fleet(
            n_users, task, n_trees=(4, 8), max_depth=4, seed=seed
        )
        store = build_store(fleet)
    return ForestServer(store), store, sorted(fleet)


def trace_rows(store, ev, seed):
    """Deterministic row block for one trace event."""
    rng = np.random.default_rng((seed, int(ev.t * 1e6), ev.n_rows))
    return rng.integers(
        0, 64, size=(ev.n_rows, store.shared.n_features), dtype=np.int32
    )


def record_batches(server, store, trace, seed, max_rows):
    """Replay the trace through a virtual-clock scheduler once and return
    the micro-batch request lists it forms — the equal-batch-size basis
    for the scheduled-vs-direct comparison."""
    from repro.sched import MicroBatcher, Scheduler, VirtualClock

    clock = VirtualClock()
    sched = Scheduler(
        server, clock=clock, batcher=MicroBatcher(max_rows=max_rows),
        safe=False,
    )
    submitted = []
    for ev in trace:
        if clock.now() < ev.t:
            clock.advance(ev.t - clock.now())
        submitted.append(
            (ev.user_id, sched.submit(ev.user_id, trace_rows(store, ev, seed)))
        )
        sched.pump()
    sched.flush()
    sched.close()
    by_batch: dict[int, list] = {}
    for u, t in submitted:
        by_batch.setdefault(t.batch_seq, []).append((u, t.rows))
    return [by_batch[k] for k in sorted(by_batch)]


def best_of(fn, repeats):
    """Best-of-N wall time: the box throttles on shared cores, so the MIN
    is the reproducible number (mean folds in scheduler noise)."""
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.time()
        result = fn()
        best = min(best, time.time() - t0)
    return best, result


def bench_throughput(server, store, batches, repeats):
    """Scheduled (submit+flush, overlap on) vs direct serve on the SAME
    recorded micro-batches, two regimes:

    * **warm** — plan cache hot on both sides, the steady state a
      long-lived session actually serves from.  This is the headline
      ``sched_vs_direct`` acceptance ratio: the scheduler's queueing +
      ticket bookkeeping (~12 us/request) must be paid back by the
      submit-thread/worker overlap, so >= 1 means scheduled serving
      sustains direct-session throughput at equal batch sizes;
    * **cold-plan** — the plan cache is cleared at the start of each
      run: direct serving pays plan + execute SERIALLY per batch, while
      the scheduler pre-plans batch k+1 on the submit thread during
      batch k's device time.  On a single-CPU jax device both stages
      contend for the GIL, so this secondary ratio is reported for
      observability, not gated.
    """
    from repro.sched import MicroBatcher, Scheduler

    n_rows = sum(len(x) for b in batches for _, x in b)

    def run_direct(cold=False):
        if cold:
            server.plan_cache.clear()
        return [server.serve(b) for b in batches]

    # one long-lived scheduler session, as production would run it — the
    # direct side likewise reuses the server, so neither run is charged
    # for construction (thread spawn, cache warmup)
    sched = Scheduler(
        server, batcher=MicroBatcher(max_rows=1 << 30), safe=False,
    )

    def run_scheduled(cold=False):
        if cold:
            server.plan_cache.clear()
        tickets = []
        for b in batches:
            for u, x in b:
                tickets.append(sched.submit(u, x))
            sched.flush(drain=False)  # one micro-batch per recorded batch
        sched.executor.drain()
        return tickets

    run_direct()       # compile + warm plan/pack caches
    run_scheduled()
    # interleave the timed runs so box-level drift (thermal, neighbors)
    # hits both sides equally
    t_direct = t_sched = t_direct_cold = t_sched_cold = float("inf")
    tickets = None
    for _ in range(repeats):
        t, _ = best_of(run_direct, 1)
        t_direct = min(t_direct, t)
        t, tk = best_of(run_scheduled, 1)
        if t < t_sched:
            t_sched, tickets = t, tk
        t, _ = best_of(lambda: run_direct(cold=True), 1)
        t_direct_cold = min(t_direct_cold, t)
        t, _ = best_of(lambda: run_scheduled(cold=True), 1)
        t_sched_cold = min(t_sched_cold, t)
    sched.close()
    direct_preds = run_direct()
    silent_wrong = 0
    it = iter(tickets)
    for preds in direct_preds:
        for p in preds:
            t = next(it)
            if t.status != "ok" or not np.array_equal(t.prediction, p):
                silent_wrong += 1
    return {
        "n_batches": len(batches),
        "n_rows": n_rows,
        "direct_warm_ms": round(t_direct * 1e3, 2),
        "direct_rows_per_s": round(n_rows / t_direct, 1),
        "sched_warm_ms": round(t_sched * 1e3, 2),
        "sched_rows_per_s": round(n_rows / t_sched, 1),
        "sched_vs_direct": round(t_direct / t_sched, 3),
        "direct_coldplan_ms": round(t_direct_cold * 1e3, 2),
        "sched_coldplan_ms": round(t_sched_cold * 1e3, 2),
        "sched_coldplan_rows_per_s": round(n_rows / t_sched_cold, 1),
        "sched_vs_direct_coldplan": round(t_direct_cold / t_sched_cold, 3),
        "mismatches_vs_direct": silent_wrong,
    }


def bench_latency(server, store, trace, seed, max_rows, slo_s):
    """Open-loop wall-clock replay: arrivals paced, deadline trigger
    live, per-request latency measured end to end.

    The measured pass runs WARM: the trace's micro-batches are first
    recorded under the virtual clock and direct-served once (compiling
    this workload's kernel shapes — batch boundaries are row-trigger
    crossings of the same arrival sequence, so the paced run forms the
    same batches), then a full paced dress rehearsal runs, and the
    second paced pass is reported.  A 1-2s jit compile mid-trace
    otherwise cascades: the queue backs up behind it and every following
    request misses its deadline — a cold-start artifact, not a
    steady-state property."""
    from repro.sched import MicroBatcher, RequestQueue, Scheduler

    for b in record_batches(server, store, trace, seed, max_rows):
        server.serve(b)
    sched = None
    for _pass in range(2):
        sched = Scheduler(
            server, queue=RequestQueue(slo_s=slo_s),
            batcher=MicroBatcher(max_rows=max_rows),
        )
        start = time.monotonic()
        for ev in trace:
            lag = ev.t - (time.monotonic() - start)
            if lag > 0:
                time.sleep(lag)
            sched.submit(ev.user_id, trace_rows(store, ev, seed))
            sched.pump()
        sched.close()
    lat = sched.latency_stats()
    lat_slack = sched.latency_stats(slack_s=slo_s)  # 2x SLO budget
    stats = sched.stats()
    return {
        "n_requests": len(trace),
        "slo_s": slo_s,
        "p50_ms": lat["p50_ms"],
        "p99_ms": lat["p99_ms"],
        "slo_attainment": lat["slo_attainment"],
        "slo_attainment_2x": lat_slack["slo_attainment"],
        "trigger_counts": stats["batcher"]["trigger_counts"],
        "plan_hit_rate": server.plan_cache.stats()["plan_hit_rate"],
    }


def bench_lifecycle(n_users, task, seed, n_requests, slo_s):
    """Drifted fleet under the virtual clock with an attached
    LifecycleDriver: autonomous recluster + rate-limited migration while
    serving; every response verified bit-exact afterwards."""
    from repro.core.compressed_predict import predict_compressed
    from repro.sched import (
        LifecycleDriver,
        MicroBatcher,
        RequestQueue,
        Scheduler,
        VirtualClock,
    )

    server, store, users = build_fleet_server(
        n_users, task, seed, drift=True
    )
    clock = VirtualClock()
    driver = LifecycleDriver(
        server, clock, poll_interval_s=0.2, low_load_rows=256,
        migrate_users_per_s=20.0, max_users_per_tick=2,
    )
    sched = Scheduler(
        server, clock=clock, queue=RequestQueue(slo_s=slo_s),
        batcher=MicroBatcher(max_rows=128), lifecycle=driver,
    )
    rng = np.random.default_rng(seed + 9)
    gen0 = store.generation
    tickets = []
    served_mid_migration = 0
    for _ in range(n_requests):
        u = users[int(rng.integers(len(users)))]
        rows = rng.integers(
            0, 64, size=(8, store.shared.n_features), dtype=np.int32
        )
        tickets.append((u, rows, sched.submit(u, rows)))
        clock.advance(0.05)
        sched.pump()
        if driver.state == "migrating":
            served_mid_migration += 1
    while driver.state == "migrating":
        clock.advance(0.1)
        sched.pump()
    sched.close()
    silent_wrong = 0
    for u, rows, t in tickets:
        ref = predict_compressed(store.hydrate(u), rows)
        if t.status != "ok" or not np.array_equal(t.prediction, ref):
            silent_wrong += 1
    lat = sched.latency_stats(slack_s=slo_s)
    dstats = driver.stats()
    return {
        "n_users": n_users,
        "n_requests": len(tickets),
        "generation": [gen0, store.generation],
        "n_reclusters": dstats["n_reclusters"],
        "n_migrated": dstats["n_migrated"],
        "n_migration_ticks": dstats["n_migration_ticks"],
        "served_mid_migration": served_mid_migration,
        "journal_state": (
            dstats["journal"]["state"] if dstats["journal"] else None
        ),
        "silent_wrong_total": silent_wrong,
        "deadline_misses_beyond_slack": lat["deadline_misses"],
        "fallback_user_fraction_after": drift_fraction(store),
    }


def drift_fraction(store):
    from repro.store.lifecycle import drift_report

    return drift_report(store)["fallback_user_fraction"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: short trace, hard assertions")
    ap.add_argument("--out", default=None)
    ap.add_argument("--users", type=int, default=100)
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--rate", type=float, default=150.0)
    ap.add_argument("--max-rows", type=int, default=512)
    ap.add_argument("--tp-max-rows", type=int, default=2048)
    ap.add_argument("--slo", type=float, default=0.25)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--lifecycle-requests", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.duration, args.rate = 1.5, 80.0
        args.repeats, args.lifecycle_requests = 5, 120
        args.slo = 0.5  # CI boxes are noisy; the smoke gate is exactness
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_sched.json"
    )

    server, store, users = build_fleet_server(
        args.users, "classification", args.seed
    )
    # throughput trace: bulk-sized requests so device time dominates
    # (that is where plan/execute overlap pays); latency trace:
    # interactive-sized requests under the SLO deadline trigger
    tp_trace = poisson_trace(
        users, args.duration, args.rate, rows_choices=(64, 128, 256),
        popularity_skew=1.1, burst_factor=2.0, seed=args.seed,
    )
    batches = record_batches(
        server, store, tp_trace, args.seed, args.tp_max_rows
    )
    throughput = bench_throughput(server, store, batches, args.repeats)
    trace = poisson_trace(
        users, args.duration, args.rate,
        popularity_skew=1.1, burst_factor=2.0, seed=args.seed,
    )
    latency = bench_latency(
        server, store, trace, args.seed, args.max_rows, args.slo
    )
    lifecycle = bench_lifecycle(
        min(args.users // 5, 20), "classification", args.seed,
        args.lifecycle_requests, args.slo,
    )

    results = {
        "benchmark": "sched",
        "smoke": bool(args.smoke),
        "n_users": args.users,
        "trace": {
            "n_events": len(trace),
            "duration_s": args.duration,
            "rate_per_s": args.rate,
            "burst_factor": 2.0,
            "popularity_skew": 1.1,
        },
        "throughput": throughput,
        "latency": latency,
        "lifecycle": lifecycle,
    }
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")

    if args.smoke:
        assert throughput["mismatches_vs_direct"] == 0, \
            "scheduled serving must be bit-exact vs direct serve"
        assert latency["plan_hit_rate"] > 0, \
            "recurring trace must hit the plan cache"
        assert lifecycle["n_reclusters"] >= 1
        assert lifecycle["silent_wrong_total"] == 0
        print("smoke assertions passed")


if __name__ == "__main__":
    main()

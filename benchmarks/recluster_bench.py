"""Codebook lifecycle benchmark (ISSUE 5 acceptance measurement).

On a DRIFTED 100-user fleet (30% of users onboarded after the fleet
codebook froze, splitting on features and carrying fit values the initial
population never produced), both tasks:

* drift: the ``drift_report`` monitor before/after (fallback user
  fraction, fallback byte overhead) — the signal that triggers a
  recluster;
* ``recluster(mode="extend")``: migration wall time, relabeled vs
  re-encoded user counts, store bytes before/after (acceptance: bytes
  after <= before), and EXPLICIT per-user bit-exact reconstruction
  against the pre-migration forests;
* warm-serving continuity: a ``ForestServer`` session is warmed on a
  clean-user batch and a late-user batch, the migration runs mid-session,
  and both batches are served again — the clean batch must HIT its cached
  pack (its users migrated by relabeling; partial invalidation keeps
  their packs), the late batch must re-gather, and every post-migration
  prediction must match per-user ``predict_compressed``;
* ``recluster(mode="full")`` on an identical second store, for the
  rebuild-vs-extend byte/time tradeoff (full mode re-encodes everyone,
  so the warm session loses every pack — measured, not asserted).

Writes machine-readable results to BENCH_recluster.json (repo root).

    PYTHONPATH=src python benchmarks/recluster_bench.py [--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.serving import ForestServer
from repro.store import (
    build_store,
    drift_report,
    make_drifted_fleet,
    recluster,
)


def _drift_summary(rep: dict) -> dict:
    return {
        k: rep[k]
        for k in (
            "codebook_generation", "n_fallback_users",
            "fallback_user_fraction", "fallback_bytes",
            "fallback_overhead_fraction", "recommend_recluster",
        )
    }


def _parity(store, requests, preds, task) -> int:
    exact = 0
    for (u, x), p in zip(requests, preds):
        ref = store.predict(u, x)
        if task == "classification":
            exact += int(np.array_equal(p, ref))
        else:
            exact += int(np.allclose(p, ref, rtol=1e-5, atol=1e-5))
    return exact


def _onboarded_store(initial, late):
    store = build_store(initial)
    t0 = time.time()
    for u, f in late.items():
        store.add_user(u, f)
    return store, time.time() - t0


def bench_fleet(
    task: str,
    n_users: int,
    late_fraction: float,
    rows_per_request: int,
    seed: int = 0,
) -> dict:
    initial, late = make_drifted_fleet(
        n_users, late_fraction=late_fraction, task=task, seed=seed,
    )
    fleet = {**initial, **late}
    store, t_onboard = _onboarded_store(initial, late)
    late_ids = sorted(late)
    clean_ids = sorted(initial)

    drift_before = drift_report(store)
    bytes_before = store.size_report()["total_bytes"]

    # ---- warm a serving session across the coming migration --------------
    rng = np.random.default_rng(seed)
    d = store.shared.n_features
    n_bins = int(store.shared.n_bins_per_feature[0])

    def batch(users):
        return [
            (u, rng.integers(0, n_bins, (rows_per_request, d)).astype(
                np.int32
            ))
            for u in users
        ]

    server = ForestServer(store)
    reqs_clean = batch(clean_ids[:4])
    reqs_late = batch(late_ids[:4])
    for _ in range(2):  # second pass hits the pack cache: session is warm
        server.serve(reqs_clean)
        server.serve(reqs_late)
    hits0 = server.plan_cache.pack_hits
    misses0 = server.plan_cache.pack_misses

    # ---- the lifecycle operation -----------------------------------------
    res = recluster(store, mode="extend")
    bit_exact = all(
        store.reconstruct(u).equals(fleet[u]) for u in store.user_ids
    )
    drift_after = drift_report(store)
    bytes_after = store.size_report()["total_bytes"]

    # ---- warm-serving continuity across the migration --------------------
    preds_clean = server.serve(reqs_clean)
    clean_pack_hit = server.plan_cache.pack_hits == hits0 + 1
    preds_late = server.serve(reqs_late)
    migrated_pack_regathered = (
        server.plan_cache.pack_misses == misses0 + 1
    )
    parity_exact = _parity(
        store, reqs_clean + reqs_late, preds_clean + preds_late, task
    )

    # ---- full rebuild on an identical store, for the tradeoff ------------
    store_full, _ = _onboarded_store(initial, late)
    res_full = recluster(store_full, mode="full")
    bit_exact_full = all(
        store_full.reconstruct(u).equals(fleet[u])
        for u in store_full.user_ids
    )

    return {
        "task": task,
        "n_users": n_users,
        "late_fraction": late_fraction,
        "onboard_time_s": round(t_onboard, 3),
        "drift_before": _drift_summary(drift_before),
        "drift_after": _drift_summary(drift_after),
        "extend": {
            "wall_time_s": round(res.wall_time_s, 3),
            "n_relabeled": res.n_relabeled,
            "n_reencoded": res.n_reencoded,
            "bytes_before": bytes_before,
            "bytes_after": bytes_after,
            "bytes_ratio": round(bytes_after / bytes_before, 4),
            "bit_exact_all_users": bit_exact,
        },
        "full": {
            "wall_time_s": round(res_full.wall_time_s, 3),
            "n_relabeled": res_full.n_relabeled,
            "n_reencoded": res_full.n_reencoded,
            "bytes_after": res_full.bytes_after,
            "bytes_ratio": round(res_full.bytes_after / bytes_before, 4),
            "bit_exact_all_users": bit_exact_full,
        },
        "warm_crossing": {
            "clean_pack_hit": clean_pack_hit,
            "migrated_pack_regathered": migrated_pack_regathered,
            "pack_invalidations": server.plan_cache.invalidations,
            "parity_exact_requests": parity_exact,
            "n_requests": len(reqs_clean) + len(reqs_late),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small fleet + classification only (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    n_users = 20 if args.quick else 100
    tasks = ["classification"] if args.quick else [
        "classification", "regression"
    ]
    fleets = [
        bench_fleet(task, n_users, late_fraction=0.3, rows_per_request=64)
        for task in tasks
    ]
    results = {"quick": args.quick, "fleets": fleets}
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_recluster.json"
    )
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()

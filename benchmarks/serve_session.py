"""Unified serving session benchmark (ISSUE 4 acceptance measurement).

On the 100-user synthetic fleet (the PR 3 serve_pipeline config), both
tasks:

* ``ForestServer`` serves the mixed request batch under ALL THREE engine
  choices — parity vs per-user ``predict_compressed`` (classification must
  be bit-exact; regression reports the float32 accumulation max error),
  and the engines must agree with each other;
* warm repeated-batch throughput: the session (plan/pack cache hot — the
  cross-batch gather memoization) vs the PR 3 pipelined path composed
  stage-by-stage WITHOUT memoization (``serve_pipelined_uncached``, i.e.
  pack -> kernel -> finalize every call).  Acceptance: the session path
  must not regress the PR 3 path (``session_vs_pr3_speedup >= 1`` up to
  timer noise);
* a repeated-users loop: plan-cache and pack-cache hit rates must be > 0
  once the same batch signature recurs (the CI smoke gate).

Writes machine-readable results to BENCH_serve_session.json (repo root).

    PYTHONPATH=src python benchmarks/serve_session.py [--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np


def best_of(fn, repeats):
    """Best-of-N wall time: the box throttles on shared cores, so the MIN
    is the reproducible number (mean folds in scheduler noise)."""
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.time()
        result = fn()
        best = min(best, time.time() - t0)
    return best, result


def parity(store, requests, preds, task):
    exact, max_err = 0, 0.0
    for (u, x), p in zip(requests, preds):
        ref = store.predict(u, x)
        if task == "classification":
            exact += int(np.array_equal(p, ref))
        else:
            if len(ref):
                max_err = max(max_err, float(np.max(np.abs(p - ref))))
            exact += int(np.allclose(p, ref, rtol=1e-4, atol=1e-4))
    return exact, max_err


def bench_fleet(task, n_users, n_requests, rows_per_request, repeats,
                loop_iters, seed=0):
    import jax

    from repro.launch.serve_store import serve_pipelined_uncached
    from repro.serving import ForestServer
    from repro.store import (
        build_store,
        make_request_batch,
        make_synthetic_fleet,
    )

    fleet = make_synthetic_fleet(n_users, task=task, seed=seed)
    store = build_store(fleet)
    requests = make_request_batch(
        store, n_requests, rows_per_request, seed + 1
    )
    n_rows = sum(len(x) for _, x in requests)
    server = ForestServer(store)

    engines = {}
    preds_by_engine = {}
    for engine in ("simple", "pipelined", "sharded"):
        server.serve(requests, engine=engine)  # compile + warm caches
        t_warm, preds = best_of(
            lambda e=engine: server.serve(requests, engine=e), repeats
        )
        exact, max_err = parity(store, requests, preds, task)
        preds_by_engine[engine] = preds
        engines[engine] = {
            "warm_ms": round(t_warm * 1e3, 2),
            "rows_per_s": round(n_rows / t_warm, 1),
            "parity_exact_requests": exact,
            "regression_max_abs_err": max_err,
        }
    agree = {
        e: all(
            np.array_equal(a, b) if task == "classification"
            else np.allclose(a, b, rtol=1e-5, atol=1e-5)
            for a, b in zip(preds_by_engine["simple"], preds_by_engine[e])
        )
        for e in ("pipelined", "sharded")
    }

    # the PR 3 pipelined path, un-memoized: pack + kernel + finalize every
    # call — what the cross-batch gather memoization is measured against
    serve_pipelined_uncached(store, requests)  # warm arena + compile
    t_pr3, _ = best_of(
        lambda: serve_pipelined_uncached(store, requests), repeats
    )
    t_session = engines["pipelined"]["warm_ms"] / 1e3

    # repeated-users loop on a FRESH session: the hit-rate smoke gate
    loop_server = ForestServer(store)
    for _ in range(loop_iters):
        loop_server.serve(requests)
    plan_cache = loop_server.plan_cache.stats()

    # the cost model's automatic choice for this batch
    auto_plan = server.plan(requests)

    return {
        "task": task,
        "n_users": n_users,
        "total_trees": sum(f.n_trees for f in fleet.values()),
        "n_requests": n_requests,
        "rows_per_request": rows_per_request,
        "n_devices": len(jax.devices()),
        "engines": engines,
        "engines_match_simple": agree,
        "auto_engine": {
            "name": auto_plan.engine.name,
            "reason": auto_plan.engine.reason,
        },
        "pr3_pipelined_warm_ms": round(t_pr3 * 1e3, 2),
        "session_vs_pr3_speedup": round(t_pr3 / t_session, 3),
        "repeated_loop": {
            "iterations": loop_iters,
            "plan_hit_rate": plan_cache["plan_hit_rate"],
            "pack_hit_rate": plan_cache["pack_hit_rate"],
        },
        "session_stats": {
            "engine_counts": dict(server.engine_counts),
            "plan_cache": server.plan_cache.stats(),
            "arena": store.arena.stats() if store.arena else None,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny fleet for CI smoke runs")
    ap.add_argument("--out", default=None)
    ap.add_argument("--users", type=int, default=100)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--loop-iters", type=int, default=10)
    args = ap.parse_args()
    if args.quick:
        args.users, args.requests, args.rows = 8, 6, 32
        args.repeats, args.loop_iters = 2, 4
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serve_session.json"
    )
    results = {
        "benchmark": "serve_session",
        "quick": bool(args.quick),
        "fleets": [
            bench_fleet(task, args.users, args.requests, args.rows,
                        args.repeats, args.loop_iters)
            for task in ("classification", "regression")
        ],
    }
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

"""Paper Table 1 — Liberty Mutual classification: per-component compressed
sizes for the light baseline vs our scheme.

    PYTHONPATH=src python -m benchmarks.table1_liberty [--full]

--full uses the paper's 50,999 x 32 size and more trees (slow on CPU);
the default is a size-reduced run that preserves the qualitative claims
(ratios, which component dominates, cluster count).
"""
from __future__ import annotations

import argparse
import json

from repro.core import compress_forest
from repro.data.tabular import spec_by_name
from repro.forest import light_report, standard_compress

from .common import compression_row, fmt_mb, train_compact


def run(full: bool = False, n_trees: int | None = None) -> dict:
    spec = spec_by_name("liberty_cls")
    n_trees = n_trees or (1000 if full else 60)
    forest, _model, _ = train_compact(
        spec,
        n_trees=n_trees,
        max_depth=12 if full else 8,
        max_obs=None if full else 6000,
    )
    light = light_report(forest)
    comp = compress_forest(forest)
    ours = comp.size_report()
    std = len(standard_compress(forest))
    row = {
        "n_trees": n_trees,
        "standard_bytes": std,
        "light": light,
        "ours": ours,
        "ratio_vs_light": light["total"] / ours["total_serialized"],
        "ratio_vs_standard": std / ours["total_serialized"],
    }
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--n-trees", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    row = run(args.full, args.n_trees)
    if args.json:
        print(json.dumps(row, indent=1, default=float))
        return
    light, ours = row["light"], row["ours"]
    print(f"Table 1 (Liberty* classification, {row['n_trees']} trees) [MB]:")
    print(f"{'method':12s} {'struct':>8s} {'names':>8s} {'splits':>8s} "
          f"{'fits':>8s} {'dict':>8s} {'total':>8s}")
    print(f"{'light':12s} {fmt_mb(light['structure']):>8s} "
          f"{fmt_mb(light['var_names']):>8s} {fmt_mb(light['split_values']):>8s} "
          f"{fmt_mb(light['fits']):>8s} {'-':>8s} {fmt_mb(light['total']):>8s}")
    print(f"{'ours':12s} {fmt_mb(ours['structure']):>8s} "
          f"{fmt_mb(ours['var_names']):>8s} {fmt_mb(ours['split_values']):>8s} "
          f"{fmt_mb(ours['fits']):>8s} {fmt_mb(ours['dictionaries']):>8s} "
          f"{fmt_mb(ours['total_serialized']):>8s}")
    print(f"ratio vs light: 1:{row['ratio_vs_light']:.2f}   "
          f"vs standard: 1:{row['ratio_vs_standard']:.2f}")


if __name__ == "__main__":
    main()

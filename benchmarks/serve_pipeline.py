"""Pipelined multi-tenant serving benchmark (ISSUE 3 tentpole measurement).

On the 100-user synthetic fleet (the PR 2 store-bench config), both tasks:

* end-to-end WARM serving rows/s for the three engines —
  ``simple`` (the PR 2 path: host re-pack + one kernel launch per tree
  chunk, at its shipped block sizes), ``pipelined`` (device tile arena +
  one double-buffered DMA launch), ``sharded`` (tree axis partitioned
  across devices + psum) — and the pipelined/sharded speedups over simple
  (acceptance target: >= 2x);
* overlap efficiency: (pack + kernel + finalize stage times, each measured
  standalone) / end-to-end time.  1.0 means the stages ran back-to-back;
  > 1.0 means the engine overlapped them.  Under interpret mode (CPU) the
  DMA pipeline is emulated serially, so this hovers near 1.0 — the number
  exists to track REAL overlap once the kernel runs on TPU hardware;
* single- vs multi-device scaling: sharded warm rows/s at 1/2/4 devices
  (re-executed subprocesses with ``--xla_force_host_platform_device_count``;
  forced host devices share the same physical cores, so CPU numbers
  validate the mechanism, not a speedup);
* parity: every engine's predictions vs per-user ``predict_compressed`` —
  classification must be bit-exact, regression reports the float32
  accumulation max error.

Writes machine-readable results to BENCH_serve_pipeline.json (repo root).

    PYTHONPATH=src python benchmarks/serve_pipeline.py [--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np


def best_of(fn, repeats):
    """Best-of-N wall time: the box throttles on shared cores, so the MIN
    is the reproducible number (mean folds in scheduler noise)."""
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.time()
        result = fn()
        best = min(best, time.time() - t0)
    return best, result


_SESSIONS: dict = {}


def _server_for(store):
    """One memoized ForestServer per store, so repeated engine timings
    share the session's plan cache (the warm path being measured)."""
    server = _SESSIONS.get(id(store))
    if server is None:
        from repro.serving import ForestServer

        server = ForestServer(store)
        _SESSIONS[id(store)] = server
    return server


def time_engine(store, requests, engine, repeats):
    server = _server_for(store)
    server.serve(requests, engine=engine)  # compile + warm
    return best_of(
        lambda: server.serve(requests, engine=engine), repeats
    )


def pipelined_stage_times(store, requests, repeats):
    """The pipelined engine's stages measured STANDALONE — the exact same
    helpers `_serve_pipelined` composes (pack = group + arena index-gather
    + chunk ranges, kernel = the one DMA launch blocked to completion,
    finalize = unsort + per-request split).  Stage-sum vs end-to-end is
    the overlap efficiency."""
    import jax

    from repro.launch.serve_store import (
        finalize_pipelined_batch,
        pack_pipelined_batch,
        run_pipelined_kernel,
    )
    from repro.serving import ENGINE_BLOCKS

    block_trees, block_obs = ENGINE_BLOCKS["pipelined"]

    def pack():
        pb = pack_pipelined_batch(store, requests, block_trees, block_obs)
        # the arena index-gather dispatches async device work: block so
        # its cost lands in THIS stage, not the kernel stage's wait
        jax.block_until_ready(pb.code)
        jax.block_until_ready(pb.fit)
        return pb

    pb = pack()

    def kernel():
        return jax.block_until_ready(run_pipelined_kernel(store, pb))

    out = kernel()  # compile

    def finalize():
        return finalize_pipelined_batch(store, requests, pb, out)

    stages = {}
    for name, fn in (("pack", pack), ("kernel", kernel),
                     ("finalize", finalize)):
        stages[name], _ = best_of(fn, repeats)
    return stages


def parity(store, requests, preds, task):
    exact, max_err = 0, 0.0
    for (u, x), p in zip(requests, preds):
        ref = store.predict(u, x)
        if task == "classification":
            exact += int(np.array_equal(p, ref))
        else:
            if len(ref):
                max_err = max(max_err, float(np.max(np.abs(p - ref))))
            exact += int(np.allclose(p, ref, rtol=1e-4, atol=1e-4))
    return exact, max_err


def bench_fleet(task, n_users, n_requests, rows_per_request, repeats,
                seed=0):
    import jax

    from repro.store import (
        build_store,
        make_request_batch,
        make_synthetic_fleet,
    )

    fleet = make_synthetic_fleet(n_users, task=task, seed=seed)
    store = build_store(fleet)
    requests = make_request_batch(
        store, n_requests, rows_per_request, seed + 1
    )
    n_rows = sum(len(x) for _, x in requests)

    engines = {}
    preds_by_engine = {}
    for engine in ("simple", "pipelined", "sharded"):
        t_warm, preds = time_engine(store, requests, engine, repeats)
        exact, max_err = parity(store, requests, preds, task)
        preds_by_engine[engine] = preds
        engines[engine] = {
            "warm_ms": round(t_warm * 1e3, 2),
            "rows_per_s": round(n_rows / t_warm, 1),
            "parity_exact_requests": exact,
            "regression_max_abs_err": max_err,
        }
    base = engines["simple"]["warm_ms"]
    for engine in ("pipelined", "sharded"):
        engines[engine]["speedup_vs_simple"] = round(
            base / engines[engine]["warm_ms"], 2
        )
    agree = {
        e: all(
            np.array_equal(a, b) if task == "classification"
            else np.allclose(a, b, rtol=1e-5, atol=1e-5)
            for a, b in zip(preds_by_engine["simple"], preds_by_engine[e])
        )
        for e in ("pipelined", "sharded")
    }

    stages = pipelined_stage_times(store, requests, repeats)
    stage_sum = sum(stages.values())
    overlap = stage_sum / (engines["pipelined"]["warm_ms"] / 1e3)

    return {
        "task": task,
        "n_users": n_users,
        "total_trees": sum(f.n_trees for f in fleet.values()),
        "n_requests": n_requests,
        "rows_per_request": rows_per_request,
        "n_devices": len(jax.devices()),
        "engines": engines,
        "engines_match_simple": agree,
        "pipelined_stages_ms": {
            k: round(v * 1e3, 2) for k, v in stages.items()
        },
        "overlap_efficiency": round(overlap, 3),
        "arena": store.arena.stats() if store.arena is not None else None,
    }


def worker_main(args) -> None:
    """Subprocess entry (one fixed device count): sharded warm rows/s."""
    import jax

    from repro.store import (
        build_store,
        make_request_batch,
        make_synthetic_fleet,
    )

    fleet = make_synthetic_fleet(args.users, task="classification",
                                 seed=0)
    store = build_store(fleet)
    requests = make_request_batch(store, args.requests, args.rows, 1)
    t_warm, _ = time_engine(store, requests, "sharded", args.repeats)
    n_rows = sum(len(x) for _, x in requests)
    print(json.dumps({
        # the ACTUAL device count, so a stray inherited XLA flag that
        # overrode the request cannot mislabel the scaling table
        "devices": len(jax.devices()),
        "sharded_warm_ms": round(t_warm * 1e3, 2),
        "sharded_rows_per_s": round(n_rows / t_warm, 1),
    }))


def device_scaling(args, device_counts):
    """Re-exec this script per device count (the XLA host-device count is
    fixed at process start) and collect the sharded engine's warm rows/s."""
    rows = []
    for n_dev in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (  # XLA flag parsing is last-wins: append OUR
            env.get("XLA_FLAGS", "")  # override after any inherited flags
            + f" --xla_force_host_platform_device_count={n_dev}"
        ).strip()
        cmd = [
            sys.executable, __file__, "--_worker-devices", str(n_dev),
            "--users", str(args.users), "--requests", str(args.requests),
            "--rows", str(args.rows), "--repeats", str(args.repeats),
        ]
        out = subprocess.run(
            cmd, env=env, capture_output=True, text=True, check=True
        )
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny fleet for CI smoke runs")
    ap.add_argument("--out", default=None)
    ap.add_argument("--users", type=int, default=100)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rows", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--_worker-devices", type=int, default=None,
                    dest="worker_devices", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.worker_devices is not None:
        worker_main(args)
        return
    if args.quick:
        args.users, args.requests, args.rows, args.repeats = 8, 6, 32, 2
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serve_pipeline.json"
    )
    results = {
        "benchmark": "serve_pipeline",
        "quick": bool(args.quick),
        "fleets": [
            bench_fleet(task, args.users, args.requests, args.rows,
                        args.repeats)
            for task in ("classification", "regression")
        ],
    }
    if not args.quick:
        results["device_scaling"] = device_scaling(args, [1, 2, 4])
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

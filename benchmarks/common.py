"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time

import numpy as np

from repro.core import compress_forest
from repro.data.tabular import TabularSpec, make_dataset, scaled
from repro.forest import (
    fit_binner,
    light_compress,
    light_report,
    standard_compress,
    to_compact_forest,
    train_forest,
)


def train_compact(
    spec: TabularSpec,
    *,
    n_trees: int,
    max_depth: int,
    max_obs: int | None = None,
    seed: int = 0,
    test_frac: float = 0.0,
):
    """Train a forest on a synthetic Table-2-matched dataset; return
    (compact Forest, ForestModel, (x_test, y_test) or None)."""
    s = scaled(spec, max_obs) if max_obs else spec
    x, y, categorical = make_dataset(s, seed=seed)
    test = None
    if test_frac > 0:
        n_test = int(len(x) * test_frac)
        x, x_test = x[:-n_test], x[-n_test:]
        y, y_test = y[:-n_test], y[-n_test:]
        test = (x_test, y_test)
    binner = fit_binner(x, categorical=categorical, n_bins=64)
    model = train_forest(
        x, y, binner,
        n_trees=n_trees, max_depth=max_depth,
        task=s.task, n_classes=s.n_classes, seed=seed,
    )
    return to_compact_forest(model), model, test


def compression_row(forest) -> dict:
    """All three schemes on one forest, sizes in bytes."""
    t0 = time.time()
    std = len(standard_compress(forest))
    light = len(light_compress(forest))
    comp = compress_forest(forest)
    ours = comp.size_report()
    return {
        "standard": std,
        "light": light,
        "ours": ours["total_serialized"],
        "ours_breakdown": ours,
        "ratio_vs_standard": std / max(ours["total_serialized"], 1),
        "ratio_vs_light": light / max(ours["total_serialized"], 1),
        "bench_s": time.time() - t0,
    }


def fmt_mb(b: float) -> str:
    return f"{b / 1e6:.3f}"

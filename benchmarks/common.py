"""Shared helpers for the paper-table benchmarks."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core import compress_forest
from repro.data.tabular import TabularSpec, make_dataset, scaled
from repro.forest import (
    fit_binner,
    light_compress,
    light_report,
    standard_compress,
    to_compact_forest,
    train_forest,
)


def train_compact(
    spec: TabularSpec,
    *,
    n_trees: int,
    max_depth: int,
    max_obs: int | None = None,
    seed: int = 0,
    test_frac: float = 0.0,
):
    """Train a forest on a synthetic Table-2-matched dataset; return
    (compact Forest, ForestModel, (x_test, y_test) or None)."""
    s = scaled(spec, max_obs) if max_obs else spec
    x, y, categorical = make_dataset(s, seed=seed)
    test = None
    if test_frac > 0:
        n_test = int(len(x) * test_frac)
        x, x_test = x[:-n_test], x[-n_test:]
        y, y_test = y[:-n_test], y[-n_test:]
        test = (x_test, y_test)
    binner = fit_binner(x, categorical=categorical, n_bins=64)
    model = train_forest(
        x, y, binner,
        n_trees=n_trees, max_depth=max_depth,
        task=s.task, n_classes=s.n_classes, seed=seed,
    )
    return to_compact_forest(model), model, test


def compression_row(forest) -> dict:
    """All three schemes on one forest, sizes in bytes."""
    t0 = time.time()
    std = len(standard_compress(forest))
    light = len(light_compress(forest))
    comp = compress_forest(forest)
    ours = comp.size_report()
    return {
        "standard": std,
        "light": light,
        "ours": ours["total_serialized"],
        "ours_breakdown": ours,
        "ratio_vs_standard": std / max(ours["total_serialized"], 1),
        "ratio_vs_light": light / max(ours["total_serialized"], 1),
        "bench_s": time.time() - t0,
    }


def fmt_mb(b: float) -> str:
    return f"{b / 1e6:.3f}"


# ---------------------------------------------------------------------------
# multi-tenant request traces (ISSUE 7)
# ---------------------------------------------------------------------------

@dataclass
class TraceEvent:
    """One arrival in a synthetic serving trace: which tenant asks for a
    prediction batch of ``n_rows`` rows at absolute time ``t``."""

    t: float
    user_id: str
    n_rows: int


def poisson_trace(
    user_ids: Sequence[str],
    duration_s: float,
    rate_per_s: float,
    *,
    rows_choices: Sequence[int] = (16, 32, 64),
    popularity_skew: float = 1.1,
    burst_factor: float = 1.0,
    burst_period_s: float = 2.0,
    burst_duty: float = 0.25,
    seed: int = 0,
) -> list[TraceEvent]:
    """Seeded multi-tenant Poisson arrival trace for the scheduler
    benchmarks — pure function of its arguments (no wall clock, no global
    RNG), so two calls with the same seed replay the identical workload.

    Arrivals are an (in)homogeneous Poisson process at ``rate_per_s``
    mean arrivals/second, sampled by THINNING: candidates are drawn at
    the peak rate and kept with probability rate(t)/peak.  With
    ``burst_factor`` > 1 the rate alternates between a burst plateau
    (``burst_factor`` × base, for ``burst_duty`` of each
    ``burst_period_s`` window) and a complementary trough, keeping the
    mean at ``rate_per_s`` — the bursty open-loop load SLO tests need.

    Tenants are drawn Zipf-like: tenant rank r gets weight
    r^-``popularity_skew`` (0 = uniform), matching the skewed popularity
    that makes plan-cache reuse matter.  Row counts are drawn uniformly
    from ``rows_choices``.
    """
    if not user_ids:
        raise ValueError("poisson_trace needs at least one user id")
    if rate_per_s <= 0 or duration_s <= 0:
        return []
    rng = np.random.default_rng(seed)
    weights = np.arange(1, len(user_ids) + 1, dtype=np.float64) \
        ** -float(popularity_skew)
    weights /= weights.sum()
    # burst plateau rate and trough rate with the same mean
    bf = max(float(burst_factor), 1.0)
    duty = min(max(float(burst_duty), 0.0), 1.0)
    hi = rate_per_s * bf
    lo = (
        rate_per_s * (1.0 - bf * duty) / (1.0 - duty)
        if duty < 1.0 else rate_per_s
    )
    lo = max(lo, 0.0)

    def rate_at(t: float) -> float:
        if bf <= 1.0 or duty in (0.0, 1.0):
            return rate_per_s
        return hi if (t % burst_period_s) < duty * burst_period_s else lo

    events: list[TraceEvent] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / hi)
        if t >= duration_s:
            break
        if rng.random() * hi > rate_at(t):
            continue  # thinned: candidate falls in the trough
        events.append(TraceEvent(
            t=t,
            user_id=user_ids[int(rng.choice(len(user_ids), p=weights))],
            n_rows=int(rng.choice(rows_choices)),
        ))
    return events

"""Paper Fig. 2 — lossy compression on (synthetic) Airfoil regression:
fit-quantization sweep (upper chart) and tree-subsampling sweep (lower).

    PYTHONPATH=src python -m benchmarks.fig2_lossy_airfoil
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import compress_forest, quantize_fits, subsample_trees
from repro.core.compressed_predict import predict_compressed
from repro.data.tabular import spec_by_name

from .common import train_compact


def _mse(comp, binner, x_test, y_test) -> float:
    xb = binner.transform(x_test)
    pred = predict_compressed(comp, xb)
    return float(np.mean((pred - y_test) ** 2))


def run(dataset: str = "airfoil_reg", n_trees: int = 40,
        bits_sweep=(3, 4, 5, 6, 7, 8, 10, 12),
        frac_sweep=(0.125, 0.25, 0.5, 0.75, 1.0),
        keep_bits: int = 7, max_obs: int | None = 1503):
    spec = spec_by_name(dataset)
    forest, model, test = train_compact(
        spec, n_trees=n_trees, max_depth=8, max_obs=max_obs, test_frac=0.2
    )
    x_test, y_test = test
    binner = model.binner

    base_comp = compress_forest(forest)
    base = {
        "mse": _mse(base_comp, binner, x_test, y_test),
        "bytes": base_comp.size_report()["total_serialized"],
    }

    import jax as _jax

    quant_rows = []
    for b in bits_sweep:
        _jax.clear_caches()
        qf, _err = quantize_fits(forest, b)
        comp = compress_forest(qf)
        quant_rows.append({
            "bits": b,
            "mse": _mse(comp, binner, x_test, y_test),
            "bytes": comp.size_report()["total_serialized"],
        })

    sub_rows = []
    qf, _ = quantize_fits(forest, keep_bits)
    for frac in frac_sweep:
        _jax.clear_caches()
        keep = max(1, int(round(frac * forest.n_trees)))
        sf = subsample_trees(qf, keep, seed=1)
        comp = compress_forest(sf)
        sub_rows.append({
            "n_trees": keep,
            "mse": _mse(comp, binner, x_test, y_test),
            "bytes": comp.size_report()["total_serialized"],
        })
    return {"lossless": base, "quantization": quant_rows,
            "subsampling": sub_rows, "dataset": dataset}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--dataset", default="airfoil_reg")
    ap.add_argument("--n-trees", type=int, default=40)
    args = ap.parse_args()
    res = run(args.dataset, args.n_trees)
    if args.json:
        print(json.dumps(res, indent=1, default=float))
        return
    b = res["lossless"]
    print(f"[{res['dataset']}] lossless: MSE {b['mse']:.4f}  "
          f"{b['bytes'] / 1e3:.1f} KB")
    print("fit quantization (upper chart):")
    print(f"{'bits':>5s} {'MSE':>10s} {'KB':>8s}")
    for r in res["quantization"]:
        print(f"{r['bits']:>5d} {r['mse']:>10.4f} {r['bytes'] / 1e3:>8.1f}")
    print("tree subsampling (lower chart):")
    print(f"{'trees':>6s} {'MSE':>10s} {'KB':>8s}")
    for r in res["subsampling"]:
        print(f"{r['n_trees']:>6d} {r['mse']:>10.4f} {r['bytes'] / 1e3:>8.1f}")


if __name__ == "__main__":
    main()

"""Beyond-paper benchmark: entropy-coded LM checkpoints (core.tensor_codec)
vs raw npz vs npz+zlib — the paper's cluster-codebook scheme applied to
transformer state.

    PYTHONPATH=src python -m benchmarks.ckpt_codec [--arch qwen2.5-3b]
"""
from __future__ import annotations

import argparse
import io
import json
import time
import zlib

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.tensor_codec import (
    compress_tensors,
    decompress_tensors,
    flatten_pytree,
)
from repro.models import init_params


def run(arch: str = "qwen2.5-3b", bits: int | None = None) -> dict:
    cfg = get_config(arch).smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # cast to bf16-like fp16 on host for the 16-bit split path
    flat = {
        k: (v.astype(np.float16) if v.dtype == np.float32 else v)
        for k, v in flatten_pytree(jax.tree.map(np.asarray, params)).items()
    }
    raw = sum(v.nbytes for v in flat.values())

    buf = io.BytesIO()
    np.savez(buf, **flat)
    npz = buf.getbuffer().nbytes
    z = sum(len(zlib.compress(v.tobytes(), 6)) for v in flat.values())

    t0 = time.time()
    comp = compress_tensors(flat, bits=bits)
    t_enc = time.time() - t0
    t0 = time.time()
    back = decompress_tensors(comp)
    t_dec = time.time() - t0
    exact = all((back[k] == flat[k]).all() for k in flat) if bits is None else None
    return {
        "arch": arch,
        "mode": "lossless" if bits is None else f"q{bits}",
        "raw_bytes": raw,
        "npz_bytes": npz,
        "zlib_bytes": z,
        "ours_bytes": comp.nbytes,
        "ratio_vs_raw": raw / comp.nbytes,
        "ratio_vs_zlib": z / comp.nbytes,
        "clusters": comp.stats.get("k"),
        "encode_s": t_enc,
        "decode_s": t_dec,
        "bit_exact": exact,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--bits", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    r = run(args.arch, args.bits)
    if args.json:
        print(json.dumps(r, indent=1, default=float))
        return
    print(f"[{r['arch']} {r['mode']}] raw {r['raw_bytes']/1e6:.2f} MB  "
          f"zlib {r['zlib_bytes']/1e6:.2f}  ours {r['ours_bytes']/1e6:.2f}  "
          f"({r['ratio_vs_raw']:.2f}x raw, {r['ratio_vs_zlib']:.2f}x zlib, "
          f"k={r['clusters']}, bit_exact={r['bit_exact']}, "
          f"enc {r['encode_s']:.1f}s dec {r['decode_s']:.1f}s)")


if __name__ == "__main__":
    main()

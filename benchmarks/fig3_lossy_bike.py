"""Paper Fig. 3 — lossy compression on (synthetic) Bike Sharing
regression; same sweeps as Fig. 2 at the larger dataset size.

    PYTHONPATH=src python -m benchmarks.fig3_lossy_bike
"""
from __future__ import annotations

import argparse
import json

from .fig2_lossy_airfoil import run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true")
    ap.add_argument("--n-trees", type=int, default=40)
    args = ap.parse_args()
    res = run("bike_reg", args.n_trees, keep_bits=12, max_obs=6000)
    if args.json:
        print(json.dumps(res, indent=1, default=float))
        return
    b = res["lossless"]
    print(f"[bike_reg] lossless: MSE {b['mse']:.4f}  {b['bytes']/1e3:.1f} KB")
    for name, key, col in (("fit quantization", "quantization", "bits"),
                           ("tree subsampling", "subsampling", "n_trees")):
        print(f"{name}:")
        for r in res[key]:
            print(f"  {col}={r[col]:>5}  MSE {r['mse']:.4f}  "
                  f"{r['bytes'] / 1e3:8.1f} KB")


if __name__ == "__main__":
    main()

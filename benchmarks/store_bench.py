"""Multi-tenant store benchmark (ISSUE 2 tentpole measurement).

For synthetic subscriber fleets at several sizes, on both tasks:

* fleet compression: shared-codebook store bytes (shared codebook + all
  per-user deltas) vs. the sum of independent per-forest
  ``CompressedForest.to_bytes()`` sizes;
* losslessness: every user's forest reconstructs bit-exactly from the
  store (``Forest.equals`` against the original, including regression
  fit-value tables);
* ragged multi-tenant serving: a mixed batch of many users' requests
  through the segment-aware Pallas kernel, rows/s against sequential
  per-user serving of the same batch, plus tile-cache hit behaviour on a
  repeat batch; the pipelined arena engine (ISSUE 3) runs the same warm
  batch so the rows/s trajectory across PRs lives in one artifact
  (deeper engine/scaling analysis: benchmarks/serve_pipeline.py);
* parity: classification predictions match per-user
  ``predict_compressed`` exactly (integer votes); regression reports the
  float32-accumulation max error.

Writes machine-readable results to BENCH_store.json (repo root).

    PYTHONPATH=src python benchmarks/store_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import compress_forest
from repro.serving import ForestServer
from repro.store import build_store, make_request_batch, make_synthetic_fleet


def bench_fleet(
    task: str,
    n_users: int,
    n_requests: int,
    rows_per_request: int,
    seed: int = 0,
) -> dict:
    fleet = make_synthetic_fleet(n_users, task=task, seed=seed)

    # ---- compression: shared codebook vs independent ----------------------
    independent_bytes = sum(
        len(compress_forest(f).to_bytes()) for f in fleet.values()
    )
    t0 = time.time()
    store = build_store(fleet)
    t_build = time.time() - t0
    rep = store.size_report()

    # ---- losslessness ----------------------------------------------------
    bit_exact = all(
        store.reconstruct(u).equals(fleet[u]) for u in store.user_ids
    )

    # ---- ragged multi-tenant serving -------------------------------------
    requests = make_request_batch(
        store, n_requests, rows_per_request, seed + 1
    )
    n_rows = n_requests * rows_per_request

    def compact(stats: dict) -> dict:
        per_user = stats.pop("per_user", {})
        rates = [v["hit_rate"] for v in per_user.values()]
        stats["mean_user_hit_rate"] = (
            round(float(np.mean(rates)), 4) if rates else 0.0
        )
        return stats

    server = ForestServer(store)

    # the PR 2 baseline path, measured at its shipped block sizes
    server.serve(requests[:2], engine="simple")  # jit warm-up
    t0 = time.time()
    preds = server.serve(requests, engine="simple")
    t_cold = time.time() - t0  # includes first-touch tile decode
    stats_cold = compact(store.cache.stats())
    t0 = time.time()
    preds_warm = server.serve(requests, engine="simple")
    t_warm = time.time() - t0  # tiles served from the LRU
    stats_warm = compact(store.cache.stats())

    # the pipelined arena engine (ISSUE 3) on the same batch: the serving
    # rows/s trajectory BENCH_store.json tracks across PRs
    server.serve(requests[:2], engine="pipelined")
    server.serve(requests, engine="pipelined")  # arena warm
    t0 = time.time()
    preds_pipe = server.serve(requests, engine="pipelined")
    t_pipe = time.time() - t0
    pipe_same = all(
        np.array_equal(a, b) if task == "classification"
        else np.allclose(a, b, rtol=1e-5, atol=1e-5)
        for a, b in zip(preds_warm, preds_pipe)
    )

    # sequential baseline: one fused per-user launch per request (each
    # user held as a one-forest session over their hydrated artifact)
    hyd = {
        u: ForestServer.from_forest(store.hydrate(u))
        for u in set(u for u, _ in requests)
    }
    for u, x in requests[:2]:
        hyd[u].predict(x)  # warm
    t0 = time.time()
    seq = [hyd[u].predict(x) for u, x in requests]
    t_seq = time.time() - t0

    exact = 0
    max_err = 0.0
    for (u, x), p, q in zip(requests, preds, seq):
        ref = store.predict(u, x)
        if task == "classification":
            exact += int(np.array_equal(p, ref) and np.array_equal(q, ref))
        else:
            max_err = max(max_err, float(np.max(np.abs(p - ref))))
            exact += int(np.allclose(p, ref, rtol=1e-4, atol=1e-4))
    warm_same = all(
        np.array_equal(a, b) for a, b in zip(preds, preds_warm)
    )

    return {
        "task": task,
        "n_users": n_users,
        "total_trees": sum(f.n_trees for f in fleet.values()),
        "build_s": round(t_build, 2),
        "compression": {
            "independent_bytes": independent_bytes,
            "store_total_bytes": rep["total_bytes"],
            "shared_codebook_bytes": rep["shared_codebook_bytes"],
            "user_delta_bytes_total": rep["user_delta_bytes_total"],
            "store_vs_independent": round(
                rep["total_bytes"] / independent_bytes, 4
            ),
            "bytes_per_user_independent": round(
                independent_bytes / n_users, 1
            ),
            "bytes_per_user_store": round(
                rep["user_delta_bytes_total"] / n_users, 1
            ),
        },
        "bit_exact_reconstruction": bit_exact,
        "serving": {
            "n_requests": n_requests,
            "rows_per_request": rows_per_request,
            "distinct_users": len(set(u for u, _ in requests)),
            "ragged_cold_ms": round(t_cold * 1e3, 1),
            "ragged_warm_ms": round(t_warm * 1e3, 1),
            "pipelined_warm_ms": round(t_pipe * 1e3, 1),
            "sequential_ms": round(t_seq * 1e3, 1),
            "ragged_warm_rows_per_s": round(n_rows / t_warm, 1),
            "pipelined_rows_per_s": round(n_rows / t_pipe, 1),
            "sequential_rows_per_s": round(n_rows / t_seq, 1),
            "speedup_vs_sequential": round(t_seq / t_warm, 2),
            "pipelined_speedup_vs_simple": round(t_warm / t_pipe, 2),
            "pipelined_matches_simple": pipe_same,
            "tile_cache_cold": stats_cold,
            "tile_cache_warm": stats_warm,
            "parity_exact_requests": exact,
            "regression_max_abs_err": max_err,
            "warm_equals_cold": warm_same,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="tiny fleet for CI smoke runs")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        fleet_sizes, n_requests, rows = [8], 6, 32
    else:
        fleet_sizes, n_requests, rows = [25, 100], 24, 128
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent / "BENCH_store.json"
    )
    results = {
        "benchmark": "store",
        "quick": bool(args.quick),
        "fleets": [
            bench_fleet(task, n, n_requests, rows)
            for n in fleet_sizes
            for task in ("classification", "regression")
        ],
    }
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

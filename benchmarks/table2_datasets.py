"""Paper Table 2 — compression across the 13 datasets (size-matched
synthetic generators; see DESIGN.md §5).

    PYTHONPATH=src python -m benchmarks.table2_datasets [--full] [--quick]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.data.tabular import TABLE2_SPECS

from .common import compression_row, fmt_mb, train_compact

QUICK = {"iris", "wages", "airfoil_reg", "airfoil_cls", "shuttle"}


def run(full: bool = False, quick: bool = False, n_trees: int | None = None):
    rows = []
    for spec in TABLE2_SPECS:
        if quick and spec.name not in QUICK:
            continue
        nt = n_trees or (1000 if full else 40)
        forest, _m, _ = train_compact(
            spec,
            n_trees=nt,
            max_depth=12 if full else 8,
            max_obs=None if full else 4000,
        )
        r = compression_row(forest)
        r["dataset"] = spec.paper_row or spec.name
        r["task"] = spec.task
        rows.append(r)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--n-trees", type=int, default=None)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = run(args.full, args.quick, args.n_trees)
    if args.json:
        print(json.dumps(rows, indent=1, default=float))
        return
    print(f"{'dataset':22s} {'std MB':>9s} {'light MB':>9s} {'ours MB':>9s} "
          f"{'vs std':>7s} {'vs light':>8s}")
    for r in rows:
        print(f"{r['dataset']:22s} {fmt_mb(r['standard']):>9s} "
              f"{fmt_mb(r['light']):>9s} {fmt_mb(r['ours']):>9s} "
              f"{r['ratio_vs_standard']:>6.1f}x {r['ratio_vs_light']:>7.2f}x")
    cls = [r for r in rows if r["task"] == "classification"]
    reg = [r for r in rows if r["task"] == "regression"]
    if cls:
        print(f"classification avg: 1:{np.mean([r['ratio_vs_standard'] for r in cls]):.1f} "
              f"vs standard, 1:{np.mean([r['ratio_vs_light'] for r in cls]):.2f} vs light")
    if reg:
        print(f"regression     avg: 1:{np.mean([r['ratio_vs_standard'] for r in reg]):.1f} "
              f"vs standard, 1:{np.mean([r['ratio_vs_light'] for r in reg]):.2f} vs light")


if __name__ == "__main__":
    main()

"""Run every paper-table benchmark at CPU-budget sizes and print a
combined report.

    PYTHONPATH=src python -m benchmarks.run          # quick versions
    PYTHONPATH=src python -m benchmarks.run --full   # paper-size (slow)
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    t_start = time.time()

    print("=" * 72)
    print("Table 1 — Liberty classification component breakdown")
    print("=" * 72)
    from . import table1_liberty

    row = table1_liberty.run(full=args.full)
    light, ours = row["light"], row["ours"]
    print(f"light : struct {light['structure']} names {light['var_names']} "
          f"splits {light['split_values']} fits {light['fits']} "
          f"total {light['total']} B")
    print(f"ours  : struct {ours['structure']} names {ours['var_names']} "
          f"splits {ours['split_values']} fits {ours['fits']} "
          f"dict {ours['dictionaries']} total {ours['total_serialized']} B")
    print(f"ratios: 1:{row['ratio_vs_light']:.2f} vs light, "
          f"1:{row['ratio_vs_standard']:.2f} vs standard")

    print()
    print("=" * 72)
    jax.clear_caches()
    print("Table 2 — 13 datasets")
    print("=" * 72)
    from . import table2_datasets

    rows = table2_datasets.run(full=args.full, quick=not args.full)
    for r in rows:
        print(f"{r['dataset']:22s} std {r['standard']:>9d}  "
              f"light {r['light']:>8d}  ours {r['ours']:>8d}  "
              f"(1:{r['ratio_vs_standard']:.1f} / 1:{r['ratio_vs_light']:.2f})")
    cls = [r for r in rows if r["task"] == "classification"]
    reg = [r for r in rows if r["task"] == "regression"]
    if cls:
        print(f"cls avg 1:{np.mean([r['ratio_vs_standard'] for r in cls]):.1f} "
              f"std / 1:{np.mean([r['ratio_vs_light'] for r in cls]):.2f} light")
    if reg:
        print(f"reg avg 1:{np.mean([r['ratio_vs_standard'] for r in reg]):.1f} "
              f"std / 1:{np.mean([r['ratio_vs_light'] for r in reg]):.2f} light")

    print()
    print("=" * 72)
    jax.clear_caches()
    print("Fig 2 — lossy (airfoil): quantization + subsampling")
    print("=" * 72)
    from . import fig2_lossy_airfoil

    res = fig2_lossy_airfoil.run(n_trees=30 if not args.full else 100)
    b = res["lossless"]
    print(f"lossless MSE {b['mse']:.4f} @ {b['bytes']/1e3:.1f} KB")
    for r in res["quantization"]:
        print(f"  {r['bits']:>2d} bits: MSE {r['mse']:.4f} "
              f"@ {r['bytes']/1e3:.1f} KB")
    for r in res["subsampling"]:
        print(f"  {r['n_trees']:>3d} trees: MSE {r['mse']:.4f} "
              f"@ {r['bytes']/1e3:.1f} KB")

    print()
    print("=" * 72)
    jax.clear_caches()
    print("Fig 3 — lossy (bike)")
    print("=" * 72)
    from .fig2_lossy_airfoil import run as lossy_run

    res = lossy_run("bike_reg", 20 if not args.full else 100,
                    keep_bits=12, max_obs=3000 if not args.full else None)
    b = res["lossless"]
    print(f"lossless MSE {b['mse']:.4f} @ {b['bytes']/1e3:.1f} KB")
    for r in res["quantization"][:4]:
        print(f"  {r['bits']:>2d} bits: MSE {r['mse']:.4f} "
              f"@ {r['bytes']/1e3:.1f} KB")

    print()
    print("=" * 72)
    jax.clear_caches()
    print("Beyond-paper — entropy-coded checkpoints (tensor codec)")
    print("=" * 72)
    from . import ckpt_codec

    r = ckpt_codec.run("qwen2.5-3b")
    print(f"lossless bf16 ckpt: raw {r['raw_bytes']/1e6:.1f} MB -> "
          f"{r['ours_bytes']/1e6:.1f} MB ({r['ratio_vs_raw']:.2f}x, "
          f"zlib gets {r['zlib_bytes']/1e6:.1f}), k={r['clusters']}, "
          f"bit_exact={r['bit_exact']}")

    print()
    print("=" * 72)
    print("Roofline summary (from experiments/dryrun)")
    print("=" * 72)
    from . import roofline

    rows = roofline.load("experiments/dryrun")
    if rows:
        import json as _json

        print(_json.dumps(roofline.summary(rows), indent=1))
    else:
        print("(no dry-run records; run python -m repro.launch.dryrun --all)")

    print(f"\nbenchmarks done in {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()

"""Tiered-residency benchmark (ISSUE 10 acceptance measurement).

Puts numbers on the residency tentpole, and in ``--smoke`` mode ASSERTS
its acceptance criteria (the CI `residency` job runs exactly that):

* **streaming build** — ``build_store_streaming`` folds the fleet into
  the durable tier in bounded waves (codebook extended per wave for the
  uncodable models only); every user must reconstruct bit-exactly
  (``Forest.equals``) from disk afterwards, and memory never holds more
  than one wave;
* **budget-bounded serving** — a fleet LARGER than the host residency
  budget is served through ``ForestServer`` with ``attach_residency``
  demoting cold deltas back to lazy placeholders: every response must be
  bit-exact vs an unbounded reference store, and the peak ACCOUNTED
  resident bytes must never exceed the budget (users-per-GB is the
  headline ratio);
* **prefetch** — the same skewed trace with the residency
  ``Prefetcher`` warming request k+1's user while request k executes
  (the executor's plan-ahead slot, driven directly so batch-formation
  noise stays out of the measurement), on vs off, served on the host
  engine so eviction-order-dependent XLA recompiles can't pollute the
  comparison: cold requests (user demoted at plan time, labelled by the
  prefetch-off run so the label is mode-independent) must still be
  bit-identical, and the overlapped read + parse + entropy decode
  should cut their latency (the full run reports cold p50/p99 both
  ways; smoke asserts hit rate > 0, budget held, zero silent wrongs).

Writes machine-readable results to BENCH_residency.json (repo root).

    PYTHONPATH=src python benchmarks/residency_bench.py [--smoke|--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import tempfile
import time

import numpy as np

from repro.serving import ForestServer
from repro.store import (
    DurableStore,
    Prefetcher,
    attach_residency,
    build_store_streaming,
    make_synthetic_fleet,
)


def _fleet(n_users: int, seed: int = 3):
    return make_synthetic_fleet(
        n_users=n_users, d=6, n_bins=12, seed=seed, n_trees=(4, 8),
        max_depth=4,
    )


def _zipf_trace(users: list[str], n_requests: int, d: int, n_bins: int,
                rows: int, seed: int) -> list[tuple[str, np.ndarray]]:
    """Skewed (zipf-ish) request trace: a hot head stays resident, the
    cold tail gets demoted — the workload residency tiers exist for."""
    rng = np.random.default_rng(seed)
    w = 1.0 / np.arange(1, len(users) + 1)
    w /= w.sum()
    return [
        (
            users[int(rng.choice(len(users), p=w))],
            rng.integers(0, n_bins, (rows, d)).astype(np.int32),
        )
        for _ in range(n_requests)
    ]


# ---------------------------------------------------------------------------
# streaming build
# ---------------------------------------------------------------------------

def bench_streaming_build(n_users: int, wave_users: int,
                          seed: int = 3) -> dict:
    fleet = _fleet(n_users, seed)
    root = tempfile.mkdtemp(prefix="residency_bench_")
    try:
        base = f"{root}/fleet"
        waves: list[dict] = []
        t0 = time.time()
        durable = build_store_streaming(
            fleet, base, wave_users=wave_users, seed=0,
            on_wave=waves.append,
        )
        build_s = time.time() - t0
        store = durable.load_store(lazy=False)
        exact = sum(store.reconstruct(u).equals(f) for u, f in fleet.items())
        stats = durable.stats()
        return {
            "n_users": n_users,
            "wave_users": wave_users,
            "n_waves": len(waves),
            "final_generation": waves[-1]["generation"],
            "waves_extended": sum(w["extended"] for w in waves),
            "build_s": round(build_s, 2),
            "live_bytes": stats["live_bytes"],
            "bit_exact_users": int(exact),
            "all_bit_exact": bool(exact == n_users),
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# budget-bounded serving
# ---------------------------------------------------------------------------

def bench_residency_serve(n_users: int, n_requests: int, rows: int,
                          budget_fractions: list[float],
                          seed: int = 5) -> list[dict]:
    fleet = _fleet(n_users, seed)
    root = tempfile.mkdtemp(prefix="residency_bench_")
    out = []
    try:
        base = f"{root}/fleet"
        build_store_streaming(fleet, base, wave_users=max(4, n_users // 4),
                              seed=0)
        ref = DurableStore.open(base).load_store(lazy=False)
        users = sorted(ref.user_ids)
        sizes = {u: len(ref._deltas[u].to_bytes()) for u in users}
        fleet_bytes = sum(sizes.values())
        trace = _zipf_trace(
            users, n_requests, ref.shared.n_features,
            int(ref.shared.n_bins_per_feature[0]), rows, seed,
        )
        oracle = [ref.predict(u, x) for u, x in trace]
        for frac in budget_fractions:
            budget = max(int(fleet_bytes * frac), max(sizes.values()))
            durable = DurableStore.open(base)
            store = durable.load_store(lazy=True)
            mgr = attach_residency(store, durable, budget_bytes=budget,
                                   clock=time.monotonic)
            server = ForestServer(store)
            peak = silent_wrong = 0
            t0 = time.time()
            for (u, x), want in zip(trace, oracle):
                got = server.serve([(u, x)])[0]
                if not np.array_equal(got, want):
                    silent_wrong += 1
                peak = max(peak, mgr.accounted_bytes())
            serve_s = time.time() - t0
            st = mgr.stats()
            out.append({
                "n_users": n_users,
                "fleet_bytes": fleet_bytes,
                "budget_fraction": frac,
                "budget_bytes": budget,
                "peak_accounted_bytes": int(peak),
                "budget_respected": bool(peak <= budget),
                "n_requests": len(trace),
                "silent_wrong": silent_wrong,
                "users_per_gb": round(n_users / (budget / 1e9), 1),
                "requests_per_s": round(len(trace) / max(serve_s, 1e-9), 1),
                "resident_users": st["resident_users"],
                "demoted_users": st["demoted_users"],
                "demotions": st["demotions"],
                "reloads": st["reloads"],
                "over_budget_events": st["over_budget_events"],
                "cold_load_ms_p50": st["cold_load_ms_p50"],
                "cold_load_ms_p99": st["cold_load_ms_p99"],
            })
        return out
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------
# prefetch on vs off through the scheduler
# ---------------------------------------------------------------------------

def bench_prefetch(n_users: int, n_steps: int, batch: int, rows: int,
                   budget_fraction: float, seed: int = 7,
                   gap_ms: float = 6.0, repeats: int = 3) -> dict:
    fleet = _fleet(n_users, seed)
    root = tempfile.mkdtemp(prefix="residency_bench_")
    try:
        base = f"{root}/fleet"
        build_store_streaming(fleet, base, wave_users=max(4, n_users // 4),
                              seed=0)
        ref = DurableStore.open(base).load_store(lazy=False)
        users = sorted(ref.user_ids)
        sizes = {u: len(ref._deltas[u].to_bytes()) for u in users}
        fleet_bytes = sum(sizes.values())
        budget = max(int(fleet_bytes * budget_fraction),
                     max(sizes.values()))
        trace = _zipf_trace(
            users, n_steps * batch, ref.shared.n_features,
            int(ref.shared.n_bins_per_feature[0]), rows, seed,
        )
        oracle = [ref.predict(u, x) for u, x in trace]

        def run(prefetch: bool):
            """Serve the trace one request at a time on the host
            (``engine="simple"``) so the measurement isolates the cost
            residency controls — shard read + parse + entropy decode +
            predict — from device-side XLA compile churn (the arena's
            buffer shapes depend on eviction order, so prefetch-on and
            prefetch-off runs would compile different kernels and the
            comparison would measure the compiler, not the tiers).
            With prefetch on, request k+1's user is warmed in the
            background after request k is served — the executor's
            plan-of-(k+1) slot — and ``gap_ms`` of inter-arrival think
            time lets the warm overlap idle time instead of contending
            with the next timed serve for the interpreter."""
            durable = DurableStore.open(base)
            store = durable.load_store(lazy=True)
            mgr = attach_residency(store, durable, budget_bytes=budget,
                                   clock=time.monotonic)
            server = ForestServer(store)
            pf = (
                # block_trees matches the simple engine's tile block so
                # staged tiles land on the keys the serve will look up
                Prefetcher(mgr, server=server, background=True,
                           block_trees=32)
                if prefetch else None
            )
            preds, cold, lat, peak = [], [], [], 0
            for k, (u, x) in enumerate(trace):
                # demoted-at-plan-time is the cold label (recorded on
                # every run; the OFF run's labels are the canonical,
                # mode-independent classification)
                cold.append(not mgr.is_resident(u))
                t0 = time.perf_counter()
                preds.append(server.serve([(u, x)], engine="simple")[0])
                lat.append((time.perf_counter() - t0) * 1e3)
                peak = max(peak, mgr.accounted_bytes())
                if pf is not None and k + 1 < len(trace):
                    pf.request([trace[k + 1][0]])
                time.sleep(gap_ms / 1e3)
            if pf is not None:
                pf.close()
            return preds, cold, np.array(lat), peak, mgr.stats()

        run(False)  # warmup: page caches + lazy imports outside timings
        # best-of-N per mode: this box's 2 shared cores make single-run
        # tail percentiles scheduler-noise-bound; correctness (bit-exact
        # predictions, budget, zero silent wrongs) is asserted on EVERY
        # run, only the latency comparison takes each mode's best run
        offs = [run(False) for _ in range(repeats)]
        ons = [run(True) for _ in range(repeats)]
        silent_wrong = sum(
            0 if all(np.array_equal(r[0][i], want) for r in offs + ons)
            else 1
            for i, want in enumerate(oracle)
        )
        peak_off = max(r[3] for r in offs)
        peak_on = max(r[3] for r in ons)
        cold_off = offs[0][1]
        idx = [i for i, c in enumerate(cold_off) if c]

        def best(runs):
            lats = [r[2][idx] for r in runs]
            return min(lats, key=lambda a: float(np.percentile(a, 99)))

        lat_off = best(offs)
        lat_on = best(ons)
        s_on = ons[0][4]

        def pct(a, q):
            return round(float(np.percentile(a, q)), 3) if a.size else None

        return {
            "n_users": n_users,
            "budget_bytes": budget,
            "fleet_bytes": fleet_bytes,
            "n_requests": len(trace),
            "n_cold_requests": len(idx),
            "silent_wrong": silent_wrong,
            "budget_respected": bool(
                peak_off <= budget and peak_on <= budget
            ),
            "prefetch_hits": s_on["prefetch_hits"],
            "prefetch_hit_rate": round(
                s_on["prefetch_hits"]
                / max(s_on["prefetch_requested"], 1), 3,
            ),
            "prefetch_errors": s_on["prefetch_errors"],
            "cold_p50_ms_off": pct(lat_off, 50),
            "cold_p99_ms_off": pct(lat_off, 99),
            "cold_p50_ms_on": pct(lat_on, 50),
            "cold_p99_ms_on": pct(lat_on, 99),
            "warm_ms_p50_on": s_on["prefetch_load_ms_p50"],
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


# ---------------------------------------------------------------------------


def _assert_smoke(results: dict) -> None:
    """The CI acceptance gate (ISSUE 10): streaming build reconstructs
    bit-exactly, the budget is never exceeded while serving a fleet
    larger than it, zero silent wrongs anywhere, and the prefetcher
    actually lands hits."""
    build = results["streaming_build"]
    assert build["all_bit_exact"], build
    assert build["n_waves"] > 1, build
    for run in results["residency_serve"]:
        assert run["budget_respected"], run
        assert run["budget_bytes"] < run["fleet_bytes"], run
        assert run["silent_wrong"] == 0, run
        assert run["over_budget_events"] == 0, run
        assert run["demotions"] > 0 and run["reloads"] > 0, run
    pf = results["prefetch"]
    assert pf["silent_wrong"] == 0, pf
    assert pf["budget_respected"], pf
    assert pf["prefetch_hits"] > 0, pf
    print("residency smoke ok")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small fleets + hard acceptance asserts (CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small fleets, no asserts")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.smoke or args.quick:
        build_users, serve_users, n_requests, rows = 12, 12, 120, 16
        pf_users, pf_steps, pf_batch = 12, 30, 4
        fractions = [0.35]
    else:
        build_users, serve_users, n_requests, rows = 48, 48, 600, 64
        pf_users, pf_steps, pf_batch = 48, 120, 4
        fractions = [0.15, 0.3, 0.6]

    results: dict = {
        "benchmark": "residency",
        "quick": bool(args.smoke or args.quick),
        "streaming_build": bench_streaming_build(
            build_users, wave_users=max(4, build_users // 4)
        ),
        "residency_serve": bench_residency_serve(
            serve_users, n_requests, rows, fractions
        ),
        "prefetch": bench_prefetch(
            pf_users, pf_steps, pf_batch, rows, budget_fraction=0.3
        ),
    }
    if args.smoke:
        _assert_smoke(results)

    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_residency.json"
    )
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()

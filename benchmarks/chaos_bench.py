"""Fault-tolerance benchmark (ISSUE 6 acceptance measurement).

Puts numbers on the three fault paths the tentpole hardened, on a
drifted fleet store and a live serving session:

* **crash recovery** — a crash-at-every-journal-step sweep over a
  journaled ``recluster(mode="extend")``: for each recorded step, the
  migration is killed there (``InjectedCrash``), the journal is
  round-tripped through its RFJ1 bytes (a real restart reads it from
  disk), and ``resume_recluster`` finishes the job.  Measured: resume
  wall time per crash point and EXPLICIT per-user bit-exactness of the
  recovered store (acceptance: every crash point recovers bit-exact);
* **degraded-mode serving** — ``serve_safe`` throughput healthy vs with
  one user's delta corrupted (quarantined; the rest of the batch still
  served) vs under injected transient arena-admission faults (bounded
  retry-with-backoff, falling back to the simple engine when retries
  are exhausted).  Parity of every served prediction against per-user
  ``predict_compressed`` is counted, not assumed;
* **corruption detection** — seeded single-bit flips over each frame
  type (RFS1/RFD1/RFT1/RFM1): every flip must either be rejected with
  a typed ``FramingError`` or decode BIT-EXACTLY (a flip in the CRC
  trailer magic demotes the frame to the legacy CRC-less read path with
  the payload intact).  Acceptance: zero silent wrong decodes.

Writes machine-readable results to BENCH_chaos.json (repo root).

    PYTHONPATH=src python benchmarks/chaos_bench.py [--quick] [--out P]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import random
import time

import numpy as np

from repro.core import predict_compressed
from repro.core.framing import FramingError
from repro.runtime.chaos import (
    CrashSchedule,
    InjectedCrash,
    TransientFaults,
    flip_bit,
    poison_user,
)
from repro.serving import ForestServer
from repro.store import (
    MigrationJournal,
    build_store,
    encode_user_delta,
    recluster,
    resume_recluster,
)
from repro.store.codebook import SharedCodebook
from repro.store.delta import UserDelta
from repro.store.fleet import make_drifted_fleet, make_synthetic_fleet
from repro.store.lifecycle import RemapTable
from repro.store.runtime import ForestStore


def _drifted_store_bytes(n_users: int, seed: int) -> tuple[bytes, dict]:
    initial, late = make_drifted_fleet(
        n_users, late_fraction=0.3, task="classification", seed=seed
    )
    store = build_store(initial)
    for u, f in late.items():
        store.add_delta(u, encode_user_delta(f, store.shared))
    return store.to_bytes(), {**initial, **late}


def bench_crash_recovery(n_users: int, seed: int = 3) -> dict:
    blob, fleet = _drifted_store_bytes(n_users, seed)

    # record the journal's step sequence with a no-crash run
    probe = ForestStore.from_bytes(blob)
    sched = CrashSchedule()
    t0 = time.time()
    recluster(probe, mode="extend", journal=MigrationJournal(), on_step=sched)
    t_uninterrupted = time.time() - t0
    steps = list(sched.steps)

    points = []
    for idx, name in enumerate(steps):
        store = ForestStore.from_bytes(blob)
        journal = MigrationJournal()
        try:
            recluster(
                store, mode="extend", journal=journal,
                on_step=CrashSchedule(fail_at=(idx,)),
            )
            raise AssertionError(f"crash at step {idx} ({name}) did not fire")
        except InjectedCrash:
            pass
        state_at_crash = journal.state
        # a real restart reads the journal back from disk
        revived = MigrationJournal.from_bytes(journal.to_bytes())
        t0 = time.time()
        if revived.state == "idle":
            recluster(store, mode="extend", journal=revived)
        else:
            resume_recluster(store, revived)
        t_resume = time.time() - t0
        bit_exact = all(
            store.reconstruct(u).equals(fleet[u]) for u in store.user_ids
        )
        points.append({
            "step": idx,
            "name": name,
            "state_at_crash": state_at_crash,
            "resume_s": round(t_resume, 4),
            "journal_committed": revived.state == "committed",
            "bit_exact_all_users": bit_exact,
        })

    return {
        "n_users": n_users,
        "n_steps": len(steps),
        "uninterrupted_s": round(t_uninterrupted, 4),
        "all_crash_points_bit_exact": all(
            p["bit_exact_all_users"] for p in points
        ),
        "worst_resume_s": max(p["resume_s"] for p in points),
        "crash_points": points,
    }


def _throughput(server, reqs, repeats: int) -> tuple[float, list]:
    statuses = server.serve_safe(reqs)  # warm / compile
    ts = []
    for _ in range(repeats):
        t0 = time.time()
        statuses = server.serve_safe(reqs)
        ts.append(time.time() - t0)
    rows = sum(x.shape[0] for _, x in reqs)
    return rows / min(ts), statuses


def _parity(store, reqs, statuses) -> int:
    exact = 0
    for (u, x), s in zip(reqs, statuses):
        if s.status != "ok":
            continue
        ref = predict_compressed(store.hydrate(u), x)
        exact += int(np.array_equal(s.prediction, ref))
    return exact


def bench_degraded_serving(
    n_users: int, rows: int, repeats: int, seed: int = 11
) -> dict:
    fleet = make_synthetic_fleet(n_users=n_users, d=5, n_bins=12, seed=seed)
    store = build_store(fleet)
    server = ForestServer(store, retry_backoff_s=0.0)
    rng = np.random.default_rng(seed)
    d = store.shared.n_features
    n_bins = int(store.shared.n_bins_per_feature[0])
    reqs = [
        (u, rng.integers(0, n_bins, (rows, d)).astype(np.int32))
        for u in store.user_ids
    ]

    healthy_rps, statuses = _throughput(server, reqs, repeats)
    healthy_parity = _parity(store, reqs, statuses)

    # ---- one user's delta corrupted: quarantine, serve the rest ----------
    victim = store.user_ids[0]
    poison_user(store, victim)
    degraded_rps, statuses = _throughput(server, reqs, repeats)
    by_status: dict[str, int] = {}
    for s in statuses:
        by_status[s.status] = by_status.get(s.status, 0) + 1
    quarantine_parity = _parity(store, reqs, statuses)
    health = server.stats()["health"]

    # ---- transient admission faults: bounded retry-with-backoff ----------
    for u in store.user_ids:
        store.arena.invalidate(u)
    faults = TransientFaults(fail_first=2)
    store.arena.admission_fault = faults
    t0 = time.time()
    retry_statuses = server.serve_safe(reqs, engine="pipelined")
    t_retry = time.time() - t0
    store.arena.admission_fault = None
    retried_ok = sum(
        1 for s in retry_statuses if s.status == "ok" and not s.degraded
    )

    return {
        "n_users": n_users,
        "rows_per_request": rows,
        "healthy": {
            "rows_per_s": round(healthy_rps, 1),
            "parity_exact_requests": healthy_parity,
            "n_ok": len(reqs),
        },
        "one_user_poisoned": {
            "rows_per_s": round(degraded_rps, 1),
            "statuses": by_status,
            "parity_exact_requests": quarantine_parity,
            "n_quarantined": health["n_quarantined"],
            "integrity_failures": health["integrity_failures"],
            "throughput_ratio_vs_healthy": round(
                degraded_rps / healthy_rps, 3
            ),
        },
        "transient_faults": {
            "injected": faults.calls,
            "retries_recorded": server.stats()["health"][
                "transient_retries"
            ],
            "batch_s": round(t_retry, 4),
            "served_ok_undegraded": retried_ok,
            "n_requests": len(reqs),
        },
    }


def bench_corruption_detection(flips_per_frame: int, seed: int = 0) -> dict:
    store = build_store(
        make_synthetic_fleet(n_users=2, d=5, n_bins=12, seed=23)
    )
    remap = RemapTable(
        old_generation=1, new_generation=2,
        vars_map=np.arange(3, dtype=np.int32),
        splits_map={1: np.arange(2, dtype=np.int32)},
        fits_map=np.arange(2, dtype=np.int32),
    )
    frames = {
        "RFS1": (store.shared.to_bytes(), SharedCodebook.from_bytes),
        "RFD1": (
            store.delta(store.user_ids[0]).to_bytes(), UserDelta.from_bytes
        ),
        "RFT1": (store.to_bytes(), ForestStore.from_bytes),
        "RFM1": (remap.to_bytes(), RemapTable.from_bytes),
    }
    rng = random.Random(seed)
    out = {}
    silent_total = 0
    for name, (blob, parse) in frames.items():
        nbits = 8 * len(blob)
        bits = rng.sample(range(nbits), min(flips_per_frame, nbits))
        typed = exact = silent = 0
        t0 = time.time()
        for bit in bits:
            try:
                reparsed = parse(flip_bit(blob, bit))
            except FramingError:
                typed += 1
                continue
            if reparsed.to_bytes() == blob:
                exact += 1
            else:
                silent += 1
        out[name] = {
            "frame_bytes": len(blob),
            "flips": len(bits),
            "typed_rejects": typed,
            "bit_exact_survivals": exact,
            "silent_wrong": silent,
            "checks_per_s": round(len(bits) / (time.time() - t0), 1),
        }
        silent_total += silent
    return {"frames": out, "silent_wrong_total": silent_total}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small fleet + fewer flips (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.quick:
        crash_users, serve_users, rows, repeats, flips = 5, 4, 64, 1, 120
    else:
        crash_users, serve_users, rows, repeats, flips = 12, 8, 256, 3, 600

    results = {
        "benchmark": "chaos",
        "quick": bool(args.quick),
        "crash_recovery": bench_crash_recovery(crash_users),
        "degraded_serving": bench_degraded_serving(
            serve_users, rows, repeats
        ),
        "corruption_detection": bench_corruption_detection(flips),
    }
    out_path = pathlib.Path(
        args.out
        or pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_chaos.json"
    )
    out_path.write_text(json.dumps(results, indent=2) + "\n")
    print(json.dumps(results, indent=2))
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
